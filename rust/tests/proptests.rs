//! Property-based tests over the coordinator invariants (DESIGN.md §6),
//! using the in-tree `testkit` harness (no proptest crate offline).

use std::collections::{BTreeMap, BTreeSet};

use earl::cluster::ClusterSpec;
use earl::dispatch::{
    assign_standins, build_merge_schedule, contiguous_runs, decode_frame,
    encode_frame, lz_compress, lz_decompress, merge_tree_depth,
    plan_alltoall, plan_centralized, plan_ingest, replan_ingest_excluding,
    satisfies, Codec, DataLayout, DispatchTensor, EpisodeBatch, FrameHeader,
    MergeSink, ReceivedBatch, StepPayload, TensorKind, TransferPayload,
    WireTensorId, WorkerReport, FRAME_HEADER_LEN, MAX_FRAME_BYTES,
    SHARD_DESC_LEN,
};
use earl::envs::{ConnectFour, Game, Outcome, TicTacToe};
use earl::parallelism::{
    decode_estimate, fit_sequences, rollout_memory, rollout_oom,
    rollout_watermark_frac, ModelShape, ParallelismConfig, ProfilePoint,
    RangeTable, Replanner, ReplanSignals, ThroughputCfg,
};
use earl::registry::Manifest;
use earl::rl::advantage::{reinforce_advantages, whiten, AdvantageCfg};
use earl::rl::episode::{Episode, EpisodeStatus, ExperienceBatch, Turn};
use earl::testkit::{check_default, gen};
use earl::tokenizer as tok;
use earl::util::json::Json;
use earl::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Dispatch invariants
// ---------------------------------------------------------------------------

fn random_layout(rng: &mut Pcg64, n_items: usize, n_workers: usize) -> DataLayout {
    DataLayout {
        n_workers,
        owner: (0..n_items).map(|_| rng.below(n_workers)).collect(),
    }
}

#[test]
fn prop_plans_deliver_consumer_layout() {
    check_default("plans_deliver", |rng| {
        let workers = gen::usize_in(rng, 2, 12);
        let items = gen::usize_in(rng, 1, 64);
        let producer = random_layout(rng, items, workers);
        let consumer = random_layout(rng, items, workers);
        let shard = 1 + rng.below(10_000) as u64;
        let controller = rng.below(workers);

        let central = plan_centralized(&producer, &consumer, shard, controller);
        let a2a = plan_alltoall(&producer, &consumer, shard);
        assert!(satisfies(&central, &producer, &consumer), "centralized");
        assert!(satisfies(&a2a, &producer, &consumer), "alltoall");
    });
}

#[test]
fn prop_alltoall_never_moves_more_bytes() {
    check_default("alltoall_bytes_minimal", |rng| {
        let workers = gen::usize_in(rng, 2, 12);
        let items = gen::usize_in(rng, 1, 64);
        let producer = random_layout(rng, items, workers);
        let consumer = random_layout(rng, items, workers);
        let shard = 1 + rng.below(10_000) as u64;

        let central = plan_centralized(&producer, &consumer, shard, 0);
        let a2a = plan_alltoall(&producer, &consumer, shard);
        assert!(a2a.total_bytes() <= central.total_bytes());
        // All-to-all moves exactly shard x (items whose owner changes).
        let moved = (0..items)
            .filter(|&i| producer.owner[i] != consumer.owner[i])
            .count() as u64;
        assert_eq!(a2a.total_bytes(), shard * moved);
    });
}

#[test]
fn prop_plan_transfers_coalesced_per_pair() {
    check_default("coalesced_pairs", |rng| {
        let workers = gen::usize_in(rng, 2, 10);
        let items = gen::usize_in(rng, 1, 80);
        let producer = random_layout(rng, items, workers);
        let consumer = random_layout(rng, items, workers);
        let a2a = plan_alltoall(&producer, &consumer, 7);
        let mut seen = BTreeMap::new();
        for t in &a2a.phases[0] {
            assert_ne!(t.src, t.dst, "self-transfer planned");
            assert!(seen.insert((t.src, t.dst), ()).is_none(), "dup pair");
        }
    });
}

// ---------------------------------------------------------------------------
// Dispatch wire-framing invariants (per-transfer header of dispatch/tcp.rs)
// ---------------------------------------------------------------------------

fn random_header(rng: &mut Pcg64) -> FrameHeader {
    // Mix uniform values with the corner cases that bit-packing bugs
    // love (0, 1, u64::MAX, single-byte boundaries).
    let pick = |rng: &mut Pcg64| match rng.below(4) {
        0 => *rng.choose(&[0u64, 1, 255, 256, u64::MAX, u64::MAX - 1]),
        _ => rng.next_u64(),
    };
    FrameHeader {
        src: pick(rng),
        epoch: pick(rng),
        bytes: pick(rng),
        n_shards: (pick(rng) & 0xFFFF_FFFF) as u32,
        checksum: pick(rng),
    }
}

#[test]
fn prop_frame_header_roundtrips() {
    check_default("frame_header_roundtrip", |rng| {
        let h = random_header(rng);
        let wire = h.encode();
        assert_eq!(wire.len(), FRAME_HEADER_LEN);
        assert_eq!(FrameHeader::decode(&wire).unwrap(), h);
        // Decoding reads only the header prefix: trailing payload bytes
        // (the receiver's buffer is header + payload) must not matter.
        let mut with_payload = wire.to_vec();
        with_payload.extend((0..rng.below(64)).map(|i| i as u8));
        assert_eq!(FrameHeader::decode(&with_payload).unwrap(), h);
    });
}

#[test]
fn prop_truncated_frame_header_is_rejected() {
    check_default("frame_header_truncated", |rng| {
        let wire = random_header(rng).encode();
        let cut = rng.below(FRAME_HEADER_LEN); // strictly short
        assert!(
            FrameHeader::decode(&wire[..cut]).is_err(),
            "decode must reject {cut}-byte header"
        );
    });
}

// ---------------------------------------------------------------------------
// Shard serialization: serialize → frame → reassemble is byte-identical
// and checksum-stable under arbitrary row splits; truncation and
// corruption are rejected.
// ---------------------------------------------------------------------------

fn random_payload(rng: &mut Pcg64) -> StepPayload {
    let rows = gen::usize_in(rng, 1, 12);
    let cols = gen::usize_in(rng, 1, 24);
    let tokens: Vec<i32> = (0..rows * cols)
        .map(|_| (rng.next_u64() & 0xFFFF) as i32 - 0x8000)
        .collect();
    let mask: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
    let adv: Vec<f32> =
        (0..rows * cols).map(|_| rng.gaussian() as f32).collect();
    StepPayload::new(vec![
        DispatchTensor::from_i32(WireTensorId::Tokens, rows, cols, &tokens)
            .unwrap(),
        DispatchTensor::from_f32(WireTensorId::Mask, rows, cols, &mask)
            .unwrap(),
        DispatchTensor::from_f32(WireTensorId::Advantages, rows, cols, &adv)
            .unwrap(),
    ])
    .unwrap()
}

#[test]
fn prop_shard_serialization_roundtrips() {
    check_default("shard_roundtrip", |rng| {
        let payload = random_payload(rng);
        let rows = payload.rows();
        // Arbitrary row split: a random nonempty subset, shuffled (the
        // serializer must sort/dedup into contiguous runs itself).
        let mut items: Vec<usize> =
            (0..rows).filter(|_| rng.below(2) == 0).collect();
        if items.is_empty() {
            items.push(rng.below(rows));
        }
        rng.shuffle(&mut items);

        let tp = TransferPayload::for_items(&payload, &items).unwrap();
        assert_eq!(
            tp.payload_bytes(),
            payload.item_bytes()
                * {
                    let mut uniq = items.clone();
                    uniq.sort_unstable();
                    uniq.dedup();
                    uniq.len() as u64
                }
        );
        // Shard table is exactly runs × tensors.
        assert_eq!(tp.shards.len(), contiguous_runs(&items).len() * 3);

        // Checksum is stable across re-serialization.
        let again = TransferPayload::for_items(&payload, &items).unwrap();
        assert_eq!(tp.checksum(), again.checksum());

        // Frame → decode → reassemble → byte-identical to the source.
        let frame = encode_frame(3, 17, &tp).unwrap();
        assert_eq!(frame, encode_frame(3, 17, &again).unwrap());
        let (header, shards) = decode_frame(&frame).unwrap();
        assert_eq!(header.bytes, tp.payload_bytes());
        assert_eq!(header.checksum, tp.checksum());
        let mut batch = ReceivedBatch::new();
        for (desc, bytes) in &shards {
            batch.insert(desc, bytes).unwrap();
        }
        batch.assert_matches(&payload, &items).unwrap();
    });
}

#[test]
fn prop_truncated_or_corrupt_frames_rejected() {
    check_default("frame_truncation", |rng| {
        let payload = random_payload(rng);
        let items: Vec<usize> = (0..payload.rows()).collect();
        let tp = TransferPayload::for_items(&payload, &items).unwrap();
        let frame = encode_frame(0, 1, &tp).unwrap();
        // Any strict prefix must fail to decode.
        let cut = rng.below(frame.len());
        assert!(
            decode_frame(&frame[..cut]).is_err(),
            "decode must reject {cut}-byte prefix of {}",
            frame.len()
        );
        // Flipping any payload byte must break the checksum.
        let body_start = frame.len() - tp.payload_bytes() as usize;
        let mut corrupt = frame.clone();
        let idx = body_start + rng.below(tp.payload_bytes() as usize);
        corrupt[idx] ^= 1 + rng.below(255) as u8;
        assert!(decode_frame(&corrupt).is_err(), "bit flip at {idx}");
    });
}

// ---------------------------------------------------------------------------
// Negotiated wire codec: LZ roundtrip byte-identity on arbitrary
// inputs, compressed frames under the same truncation/corruption
// contract as raw ones, and single-field header mutations rejected at
// the guards — before any header-declared allocation.
// ---------------------------------------------------------------------------

fn random_bytes(rng: &mut Pcg64) -> Vec<u8> {
    match rng.below(4) {
        // Incompressible: uniform noise.
        0 => (0..gen::usize_in(rng, 0, 600))
            .map(|_| rng.next_u64() as u8)
            .collect(),
        // Highly compressible: one long run.
        1 => vec![rng.next_u64() as u8; gen::usize_in(rng, 0, 600)],
        // Token-like: a small repeating alphabet with jitter.
        2 => {
            let alphabet: Vec<u8> =
                (0..gen::usize_in(rng, 1, 8)).map(|i| i as u8 * 17).collect();
            (0..gen::usize_in(rng, 0, 600))
                .map(|_| *rng.choose(&alphabet))
                .collect()
        }
        // Self-overlap stress: a short motif tiled past the window.
        _ => {
            let motif: Vec<u8> = (0..gen::usize_in(rng, 1, 5))
                .map(|_| rng.next_u64() as u8)
                .collect();
            let n = gen::usize_in(rng, 0, 600);
            (0..n).map(|i| motif[i % motif.len()]).collect()
        }
    }
}

#[test]
fn prop_lz_roundtrips_arbitrary_bytes() {
    check_default("lz_roundtrip", |rng| {
        let src = random_bytes(rng);
        let packed = lz_compress(&src);
        let back = lz_decompress(&packed, src.len()).unwrap_or_else(|e| {
            panic!("lz roundtrip failed for {} bytes: {e}", src.len())
        });
        assert_eq!(back, src, "lossless codec drifted");
        // The declared size is part of the contract: a stream that
        // inflates to anything but `expect` is a framing error, both
        // ways (truncated payload and trailing garbage).
        if !src.is_empty() {
            assert!(lz_decompress(&packed, src.len() - 1).is_err());
        }
        assert!(lz_decompress(&packed, src.len() + 1).is_err());
    });
}

/// A payload whose Tokens/Mask planes compress (small alphabet,
/// constant mask) while Advantages stay incompressible noise — the
/// shape `compresses_well` is tuned for.
fn compressible_payload(rng: &mut Pcg64) -> StepPayload {
    let rows = gen::usize_in(rng, 1, 8);
    let cols = gen::usize_in(rng, 8, 64);
    let tokens: Vec<i32> =
        (0..rows * cols).map(|_| rng.below(7) as i32).collect();
    let mask: Vec<f32> = vec![1.0; rows * cols];
    let adv: Vec<f32> =
        (0..rows * cols).map(|_| rng.gaussian() as f32).collect();
    StepPayload::new(vec![
        DispatchTensor::from_i32(WireTensorId::Tokens, rows, cols, &tokens)
            .unwrap(),
        DispatchTensor::from_f32(WireTensorId::Mask, rows, cols, &mask)
            .unwrap(),
        DispatchTensor::from_f32(WireTensorId::Advantages, rows, cols, &adv)
            .unwrap(),
    ])
    .unwrap()
}

#[test]
fn prop_compressed_frames_decode_byte_identical() {
    check_default("codec_frame_roundtrip", |rng| {
        let payload = compressible_payload(rng);
        let items: Vec<usize> = (0..payload.rows()).collect();
        let raw = TransferPayload::for_items(&payload, &items).unwrap();
        let tp = TransferPayload::for_items(&payload, &items)
            .unwrap()
            .compress(Codec::Lz);
        // Compression never grows the wire form (a shard only keeps
        // its packed bytes when strictly smaller) and never touches
        // the logical byte count.
        assert!(tp.wire_bytes() <= raw.wire_bytes());
        assert_eq!(tp.payload_bytes(), raw.payload_bytes());
        for (desc, _) in &tp.shards {
            desc.check_wire_bytes().unwrap();
            if desc.codec == Codec::Lz {
                assert!(desc.tensor.compresses_well(), "{:?}", desc.tensor);
            }
        }
        // The frame decodes back to the exact source bytes.
        let frame = encode_frame(1, 9, &tp).unwrap();
        let (header, shards) = decode_frame(&frame).unwrap();
        assert_eq!(header.bytes, tp.wire_bytes());
        let mut batch = ReceivedBatch::new();
        for (desc, bytes) in &shards {
            batch.insert(desc, bytes).unwrap();
        }
        batch.assert_matches(&payload, &items).unwrap();
    });
}

#[test]
fn prop_truncated_or_corrupt_compressed_frames_rejected() {
    check_default("codec_frame_corruption", |rng| {
        let payload = compressible_payload(rng);
        let items: Vec<usize> = (0..payload.rows()).collect();
        let tp = TransferPayload::for_items(&payload, &items)
            .unwrap()
            .compress(Codec::Lz);
        let frame = encode_frame(0, 1, &tp).unwrap();
        // Any strict prefix fails — including cuts inside a compressed
        // shard body, which must not decompress "short but clean".
        let cut = rng.below(frame.len());
        assert!(
            decode_frame(&frame[..cut]).is_err(),
            "decode must reject {cut}-byte prefix of {}",
            frame.len()
        );
        // Any single-byte flip past the magic fails: the checksum is
        // computed over the *wire* (compressed) bytes, so corruption is
        // caught before any decompressed data escapes.
        let idx = 4 + rng.below(frame.len() - 4);
        let mut corrupt = frame.clone();
        corrupt[idx] ^= 1 + rng.below(255) as u8;
        assert!(decode_frame(&corrupt).is_err(), "bit flip at {idx}");
    });
}

#[test]
fn prop_header_field_mutations_rejected_at_the_guards() {
    use earl::dispatch::wire::MAX_FRAME_SHARDS;
    check_default("header_mutation_guards", |rng| {
        let payload = compressible_payload(rng);
        let items: Vec<usize> = (0..payload.rows()).collect();
        let tp = TransferPayload::for_items(&payload, &items).unwrap();
        let frame = encode_frame(2, 3, &tp).unwrap();
        let header = FrameHeader::decode(&frame).unwrap();

        // Mutate exactly one verified header field. Oversized `bytes` /
        // `n_shards` claims must die at the MAX_* guards — this test
        // completing at all is the allocation evidence, since honoring
        // a u64::MAX-ish claim would OOM before failing.
        let mut bad = header;
        match rng.below(3) {
            0 => {
                bad.bytes = MAX_FRAME_BYTES
                    + 1
                    + (rng.next_u64() % (u64::MAX / 2 - MAX_FRAME_BYTES));
            }
            1 => {
                bad.n_shards = MAX_FRAME_SHARDS
                    + 1
                    + (rng.next_u64() as u32 % (u32::MAX - MAX_FRAME_SHARDS));
            }
            _ => {
                bad.checksum ^= 1 + rng.next_u64() % (u64::MAX - 1);
            }
        }
        let mut mutated = frame.clone();
        mutated[..FRAME_HEADER_LEN].copy_from_slice(&bad.encode());
        assert!(
            decode_frame(&mutated).is_err(),
            "mutated header accepted: {bad:?}"
        );

        // In-range but wrong declarations are caught by the descriptor
        // cross-check (sum of per-shard wire bytes), not trusted.
        let mut skew = header;
        skew.bytes ^= 1 + rng.below(1 << 20) as u64;
        let mut skewed = frame;
        skewed[..FRAME_HEADER_LEN].copy_from_slice(&skew.encode());
        assert!(decode_frame(&skewed).is_err(), "byte-count skew accepted");
    });
}

#[test]
fn prop_shard_desc_codec_consistency_enforced() {
    check_default("shard_desc_codec_guard", |rng| {
        // An identity shard must declare wire == payload bytes; an LZ
        // shard strictly fewer. Random (codec, wire, payload) triples
        // that violate either rule are rejected before any payload is
        // read.
        let rows = 1 + rng.below(64) as u32;
        let row_bytes = 1 + rng.below(4096) as u32;
        let payload = rows as u64 * row_bytes as u64;
        let desc = |codec, wire_bytes| earl::dispatch::ShardDesc {
            tensor: WireTensorId::Tokens,
            dtype: earl::dispatch::WireDtype::I32,
            codec,
            row_start: 0,
            rows,
            row_bytes,
            wire_bytes,
        };
        assert!(desc(Codec::None, payload).check_wire_bytes().is_ok());
        assert!(desc(Codec::None, payload + 1).check_wire_bytes().is_err());
        assert!(
            desc(Codec::None, payload - 1).check_wire_bytes().is_err()
        );
        assert!(desc(Codec::Lz, payload).check_wire_bytes().is_err());
        assert!(
            desc(Codec::Lz, payload + rng.next_u64() % (1 << 30))
                .check_wire_bytes()
                .is_err(),
            "inflating 'compressed' shard accepted"
        );
        if payload > 1 {
            let smaller = 1 + rng.next_u64() % (payload - 1);
            assert!(desc(Codec::Lz, smaller).check_wire_bytes().is_ok());
        }
        // The serialized descriptor roundtrips its codec byte.
        let d = desc(Codec::Lz, payload.saturating_sub(2).max(1));
        let wire = d.encode();
        assert_eq!(wire.len(), SHARD_DESC_LEN);
        assert_eq!(earl::dispatch::ShardDesc::decode(&wire).unwrap(), d);
    });
}

// ---------------------------------------------------------------------------
// Aggregation partition (paper §3.3): every tensor routes exactly once —
// wire XOR controller — and membership is decided by needs_aggregation.
// ---------------------------------------------------------------------------

#[test]
fn prop_aggregation_partition_routes_each_tensor_once() {
    // Real (non-control) tensor ids a payload can stage.
    const STAGEABLE: [WireTensorId; 4] = [
        WireTensorId::Tokens,
        WireTensorId::Mask,
        WireTensorId::Advantages,
        WireTensorId::RefLogprobs,
    ];
    check_default("aggregation_partition", |rng| {
        let rows = gen::usize_in(rng, 1, 6);
        let cols = gen::usize_in(rng, 1, 8);
        // A random nonempty subset of the stageable tensors.
        let mut ids: Vec<WireTensorId> =
            STAGEABLE.iter().copied().filter(|_| rng.below(2) == 0).collect();
        if ids.is_empty() {
            ids.push(*rng.choose(&STAGEABLE));
        }
        let tensors: Vec<DispatchTensor> = ids
            .iter()
            .map(|&id| match id {
                WireTensorId::Tokens => DispatchTensor::from_i32(
                    id,
                    rows,
                    cols,
                    &vec![0i32; rows * cols],
                )
                .unwrap(),
                _ => DispatchTensor::from_f32(
                    id,
                    rows,
                    cols,
                    &vec![0f32; rows * cols],
                )
                .unwrap(),
            })
            .collect();
        let payload = StepPayload::new(tensors).unwrap();
        let (wire, controller) = payload.partition_aggregation();

        // Exactly once: wire ∪ controller == staged, wire ∩ controller == ∅.
        assert_eq!(wire.len() + controller.len(), ids.len());
        let mut routed: Vec<WireTensorId> = wire
            .iter()
            .chain(controller.iter())
            .map(|t| t.id)
            .collect();
        routed.sort();
        let mut want = ids.clone();
        want.sort();
        assert_eq!(routed, want, "some tensor routed zero or two times");
        // Membership is needs_aggregation, both directions.
        assert!(wire.iter().all(|t| !t.id.needs_aggregation()));
        assert!(controller.iter().all(|t| t.id.needs_aggregation()));

        // Byte accounting: wire + controller item bytes == full.
        let wire_bytes: u64 =
            wire.iter().map(|t| t.row_bytes() as u64).sum();
        let ctrl_bytes: u64 =
            controller.iter().map(|t| t.row_bytes() as u64).sum();
        assert_eq!(wire_bytes + ctrl_bytes, payload.item_bytes());

        // wire_subset agrees with the partition (or fails iff empty).
        match payload.wire_subset() {
            Ok(sub) => assert_eq!(sub.item_bytes(), wire_bytes),
            Err(_) => assert!(wire.is_empty()),
        }
    });
}

#[test]
fn prop_wire_and_layout_aggregation_tags_agree() {
    // The WireTensorId tags must mirror the layout-level TensorKind
    // tags for the tensors that exist in both vocabularies.
    assert_eq!(
        WireTensorId::Advantages.needs_aggregation(),
        TensorKind::Advantages.needs_aggregation()
    );
    assert_eq!(
        WireTensorId::RefLogprobs.needs_aggregation(),
        TensorKind::RefLogprobs.needs_aggregation()
    );
    assert_eq!(
        WireTensorId::Tokens.needs_aggregation(),
        TensorKind::TokenIds.needs_aggregation()
    );
    assert_eq!(
        WireTensorId::Mask.needs_aggregation(),
        TensorKind::LossMask.needs_aggregation()
    );
}

#[test]
fn prop_ingest_scatter_routes_every_row_once() {
    check_default("ingest_scatter", |rng| {
        let workers = gen::usize_in(rng, 1, 10);
        let items = gen::usize_in(rng, 1, 64);
        let consumer = random_layout(rng, items, workers);
        let shard = 1 + rng.below(10_000) as u64;
        let plan = plan_ingest(&consumer, shard);
        assert_eq!(plan.phases.len(), 1);
        let mut seen = BTreeMap::new();
        for t in &plan.phases[0] {
            assert_eq!(t.src, 0, "scatter leaves the coordinator slot");
            assert_eq!(t.bytes, shard * t.items.len() as u64);
            assert!(!t.items.is_empty(), "empty transfer planned");
            for &i in &t.items {
                assert_eq!(consumer.owner[i], t.dst, "row to wrong worker");
                assert!(seen.insert(i, t.dst).is_none(), "row {i} twice");
            }
        }
        assert_eq!(seen.len(), items, "some row never shipped");
        assert_eq!(plan.total_bytes(), shard * items as u64);
    });
}

#[test]
fn prop_replan_routes_every_dead_workers_row_exactly_once() {
    check_default("ingest_replan", |rng| {
        let workers = gen::usize_in(rng, 2, 10);
        let items = gen::usize_in(rng, 1, 64);
        let consumer = random_layout(rng, items, workers);
        let shard = 1 + rng.below(10_000) as u64;
        // Kill a random strict subset of the workers (Fisher–Yates,
        // then split).
        let mut ids: Vec<usize> = (0..workers).collect();
        for i in (1..ids.len()).rev() {
            ids.swap(i, rng.below(i + 1));
        }
        let n_dead = gen::usize_in(rng, 1, workers - 1);
        let (dead, survivors) = ids.split_at(n_dead);
        let dead_set: BTreeSet<usize> = dead.iter().copied().collect();
        let surv_set: BTreeSet<usize> = survivors.iter().copied().collect();
        let standin: BTreeMap<usize, usize> =
            assign_standins(dead, survivors).into_iter().collect();

        let plan = replan_ingest_excluding(&consumer, shard, dead, survivors);
        assert_eq!(plan.phases.len(), 1);
        assert_eq!(plan.strategy, "ingest-replan");
        let mut seen = BTreeMap::new();
        for t in &plan.phases[0] {
            assert_eq!(t.src, 0, "re-plan leaves the coordinator slot");
            assert!(
                surv_set.contains(&t.dst),
                "re-plan routed rows to a dead worker {}",
                t.dst
            );
            assert_eq!(t.bytes, shard * t.items.len() as u64);
            assert!(!t.items.is_empty(), "empty transfer re-planned");
            for &i in &t.items {
                let owner = consumer.owner[i];
                assert!(
                    dead_set.contains(&owner),
                    "row {i} of survivor {owner} re-shipped"
                );
                assert_eq!(
                    t.dst, standin[&owner],
                    "row {i} sent to the wrong stand-in"
                );
                assert!(seen.insert(i, t.dst).is_none(), "row {i} twice");
            }
        }
        let expect = consumer
            .owner
            .iter()
            .filter(|o| dead_set.contains(*o))
            .count();
        assert_eq!(seen.len(), expect, "a dead worker's row never re-shipped");
        assert_eq!(plan.total_bytes(), shard * expect as u64);
    });
}

#[test]
fn prop_merge_schedule_reduces_every_leaf_to_one_reply() {
    check_default("merge_schedule", |rng| {
        let n = gen::usize_in(rng, 2, 12);
        let conns = gen::usize_in(rng, 1, n);
        let workers: Vec<u32> = (0..n as u32).collect();
        let hosts: Vec<usize> = (0..n).map(|_| rng.below(conns)).collect();
        let addrs: Vec<String> = (0..conns)
            .map(|c| format!("127.0.0.1:{}", 9000 + c))
            .collect();
        let schedule = build_merge_schedule(&workers, &hosts, &addrs).unwrap();

        let mut replies = 0usize;
        let mut folds = 0usize;
        let mut consumed: BTreeSet<u32> = BTreeSet::new();
        for (&conn, ops) in &schedule {
            assert!(conn < conns, "schedule names unknown connection {conn}");
            for op in ops {
                assert_eq!(
                    op.out_key, op.inputs[0],
                    "fold must keep the lowest input key"
                );
                assert!(
                    op.inputs.windows(2).all(|w| w[1] > w[0]),
                    "op inputs must be ascending"
                );
                for &k in &op.inputs {
                    assert!(
                        workers.contains(&k),
                        "op references unknown leaf {k}"
                    );
                    consumed.insert(k);
                }
                match &op.sink {
                    MergeSink::Reply => {
                        replies += 1;
                        assert_eq!(
                            op.out_key, workers[0],
                            "the reply must be the root of the tree"
                        );
                        assert_eq!(
                            conn, hosts[0],
                            "the reply runs on the leftmost leaf's host"
                        );
                    }
                    MergeSink::Peer(addr) => {
                        assert!(
                            addrs.contains(addr),
                            "peer sink dials unknown address {addr}"
                        );
                    }
                    MergeSink::Store => {}
                }
                match op.inputs.len() {
                    2 => folds += 1,
                    1 => assert!(
                        matches!(op.sink, MergeSink::Peer(_)),
                        "single-input ops only exist to forward a leaf"
                    ),
                    k => panic!("op with {k} inputs"),
                }
            }
        }
        assert_eq!(replies, 1, "exactly one op reports to the coordinator");
        assert_eq!(folds, n - 1, "a binary tree over {n} leaves pair-merges");
        assert_eq!(
            consumed.len(),
            n,
            "every leaf report must be consumed by the tree"
        );
        // Depth is ceil(log2 n): the coordinator's O(log n) guarantee.
        let depth = merge_tree_depth(n);
        assert!((1u64 << depth) >= n as u64);
        assert!((1u64 << (depth - 1)) < n as u64);
    });
}

// ---------------------------------------------------------------------------
// Ingest result frames: encode → decode is byte-identical; truncation
// and corruption are rejected (extends the shard suite to the frames
// workers answer with).
// ---------------------------------------------------------------------------

fn random_report(rng: &mut Pcg64) -> WorkerReport {
    WorkerReport {
        worker: rng.below(64) as u32,
        step: rng.next_u64() >> 16,
        rows: rng.below(1000) as u64,
        gen_tokens: rng.below(100_000) as u64,
        loss_sum: rng.gaussian() * 100.0,
        update_seconds: rng.f64(),
        grad: gen::vec_of(rng, 1, 64, |r| (r.gaussian() * 3.0) as f32),
        hist_counts: gen::vec_of(rng, 1, 12, |r| r.below(1000) as u64),
    }
}

#[test]
fn prop_result_frames_roundtrip_byte_identical() {
    check_default("result_frame_roundtrip", |rng| {
        let rep = random_report(rng);
        let frame = rep.encode_frame().unwrap();
        // Re-encoding is byte-identical (stable wire form).
        assert_eq!(frame, rep.encode_frame().unwrap());
        let back = WorkerReport::decode_frame(&frame).unwrap();
        assert_eq!(back, rep);
    });
}

#[test]
fn prop_result_frames_reject_truncation_and_corruption() {
    check_default("result_frame_corruption", |rng| {
        let rep = random_report(rng);
        let frame = rep.encode_frame().unwrap();
        // Any strict prefix fails.
        let cut = rng.below(frame.len());
        assert!(
            WorkerReport::decode_frame(&frame[..cut]).is_err(),
            "decode must reject {cut}-byte prefix of {}",
            frame.len()
        );
        // Any single-byte flip past the magic fails (length, body, or
        // checksum corruption — never silently accepted). Flips inside
        // the 4-byte magic are rejected as a bad magic.
        let idx = rng.below(frame.len());
        let mut corrupt = frame.clone();
        corrupt[idx] ^= 1 + rng.below(255) as u8;
        assert!(
            WorkerReport::decode_frame(&corrupt).is_err(),
            "bit flip at {idx} must be rejected"
        );
    });
}

// ---------------------------------------------------------------------------
// Fleet wire discipline: the worker manifest is a set (join order can
// never leak into its bytes or checksum), and episode batches obey the
// same roundtrip / any-byte-flip contract as result frames.
// ---------------------------------------------------------------------------

#[test]
fn prop_manifest_bytes_are_join_order_invariant() {
    check_default("manifest_join_order", |rng| {
        let n = gen::usize_in(rng, 1, 10);
        let entries: Vec<(u64, String)> = (0..n as u64)
            .map(|w| (w, format!("10.0.0.{}:{}", w + 1, 7000 + rng.below(2000))))
            .collect();
        let mut a = Manifest::new();
        for (w, addr) in &entries {
            a.join(*w, addr);
        }
        // Admit the same set in a random permutation.
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            order.swap(i, rng.below(i + 1));
        }
        let mut b = Manifest::new();
        for &i in &order {
            let (w, addr) = &entries[i];
            b.join(*w, addr);
        }
        assert_eq!(a, b);
        assert_eq!(a.encode().unwrap(), b.encode().unwrap());
        assert_eq!(a.checksum().unwrap(), b.checksum().unwrap());
        // The wire form roundtrips, and plans always walk ascending ids
        // regardless of admission order.
        assert_eq!(Manifest::decode(&a.encode().unwrap()).unwrap(), a);
        let ids: Vec<u64> = b.workers().map(|e| e.worker).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        // A rejoin bumps the generation and must change the fingerprint:
        // a coordinator can tell a restarted worker from a stale one.
        let before = b.checksum().unwrap();
        let (w, addr) = &entries[rng.below(n)];
        assert_eq!(b.join(*w, addr), 1);
        assert_ne!(b.checksum().unwrap(), before);
    });
}

fn random_episode_batch(rng: &mut Pcg64) -> EpisodeBatch {
    let n = gen::usize_in(rng, 1, 5);
    let episodes: Vec<Episode> = (0..n)
        .map(|_| {
            let n_turns = gen::usize_in(rng, 1, 4);
            let reward = *rng.choose(&[-1.0f32, 0.0, 1.0]);
            let mut ep = synth_episode(rng, n_turns, reward);
            ep.status = *rng.choose(&[
                EpisodeStatus::Finished,
                EpisodeStatus::Illegal,
                EpisodeStatus::Truncated,
            ]);
            // Cover both arms of the action wire code (0 = None).
            for t in ep.turns.iter_mut() {
                if rng.below(2) == 0 {
                    t.action = Some(rng.below(9));
                }
            }
            ep
        })
        .collect();
    EpisodeBatch {
        worker: rng.below(64) as u32,
        step: rng.next_u64() >> 16,
        snapshot_step: rng.below(1000) as u64,
        episodes,
    }
}

#[test]
fn prop_episode_batches_roundtrip_byte_identical() {
    check_default("episode_batch_roundtrip", |rng| {
        let batch = random_episode_batch(rng);
        let frame = batch.encode_frame().unwrap();
        // Re-encoding is byte-identical (stable wire form).
        assert_eq!(frame, batch.encode_frame().unwrap());
        let back = EpisodeBatch::decode_frame(&frame).unwrap();
        assert_eq!(back, batch);
    });
}

#[test]
fn prop_episode_batches_reject_truncation_and_corruption() {
    check_default("episode_batch_corruption", |rng| {
        let batch = random_episode_batch(rng);
        let frame = batch.encode_frame().unwrap();
        // Any strict prefix fails.
        let cut = rng.below(frame.len());
        assert!(
            EpisodeBatch::decode_frame(&frame[..cut]).is_err(),
            "decode must reject {cut}-byte prefix of {}",
            frame.len()
        );
        // Any single-byte flip fails: magic, length, body, or checksum
        // corruption is never silently accepted into training data.
        let idx = rng.below(frame.len());
        let mut corrupt = frame.clone();
        corrupt[idx] ^= 1 + rng.below(255) as u8;
        assert!(
            EpisodeBatch::decode_frame(&corrupt).is_err(),
            "bit flip at {idx} must be rejected"
        );
    });
}

#[test]
fn prop_stale_epoch_frames_are_rejected() {
    check_default("frame_header_stale_epoch", |rng| {
        let current = rng.next_u64();
        let h = random_header(rng);
        // The receive path keeps a completion iff its epoch matches the
        // current execution exactly — older (timed-out predecessor) and
        // newer (impossible, but never trust the wire) epochs both drop.
        assert_eq!(h.matches_epoch(current), h.epoch == current);
        let live = FrameHeader { epoch: current, ..h };
        assert!(live.matches_epoch(current));
        let stale = FrameHeader { epoch: current.wrapping_sub(1 + rng.below(1000) as u64), ..h };
        assert!(!stale.matches_epoch(current));
        // Roundtrip does not disturb the epoch check.
        let decoded = FrameHeader::decode(&stale.encode()).unwrap();
        assert!(!decoded.matches_epoch(current));
    });
}

// ---------------------------------------------------------------------------
// Selector / throughput invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_selector_never_picks_oom_config() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    let tcfg = ThroughputCfg::default();
    check_default("selector_no_oom", |rng| {
        let responses = *rng.choose(&[32usize, 64, 128]);
        let ctx_grid = [2048usize, 4096, 8192, 16384, 32768];
        let points: Vec<ProfilePoint<usize>> = ctx_grid
            .iter()
            .flat_map(|&ctx| [2usize, 4, 8].map(move |tp| (ctx, tp)))
            .map(|(ctx, tp)| ProfilePoint {
                config: tp,
                ctx,
                tgs: decode_estimate(
                    &shape,
                    &cluster,
                    ParallelismConfig::tp(tp),
                    &tcfg,
                    ctx,
                    responses,
                )
                .map(|e| e.tgs),
            })
            .collect();
        let table = RangeTable::from_profile(&points).expect("feasible");
        // Whatever ctx we query, the selected config must not OOM there
        // (at the profiled grid resolution).
        let ctx = *rng.choose(&ctx_grid);
        let (_, tp, _) = table.lookup(ctx);
        assert!(
            decode_estimate(
                &shape,
                &cluster,
                ParallelismConfig::tp(tp),
                &tcfg,
                ctx,
                responses
            )
            .is_some(),
            "selector chose TP{tp} which OOMs at ctx {ctx} resp {responses}"
        );
    });
}

#[test]
fn prop_memory_estimator_monotone() {
    let shape = ModelShape::qwen2_5_72b();
    check_default("memory_monotone", |rng| {
        let tp = *rng.choose(&[2usize, 4, 8]);
        let ctx = 1024 * gen::usize_in(rng, 1, 32);
        let resp = gen::usize_in(rng, 1, 128);
        let cfg = ParallelismConfig::tp(tp);
        let base = rollout_memory(&shape, cfg, ctx, resp);
        let more_ctx = rollout_memory(&shape, cfg, ctx * 2, resp);
        let more_resp = rollout_memory(&shape, cfg, ctx, resp * 2);
        assert!(more_ctx.kv_demand >= base.kv_demand);
        assert!(more_resp.kv_demand >= base.kv_demand);
        // Doubling TP halves per-GPU weights (within rounding).
        if tp <= 4 {
            let half =
                rollout_memory(&shape, ParallelismConfig::tp(tp * 2), ctx, resp);
            assert!(half.weights <= base.weights / 2 + 1);
        }
    });
}

#[test]
fn prop_tgs_decreases_with_context() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    let tcfg = ThroughputCfg::default();
    check_default("tgs_monotone_ctx", |rng| {
        let tp = *rng.choose(&[4usize, 8]);
        let resp = *rng.choose(&[32usize, 64]);
        let ctx = 1024 * gen::usize_in(rng, 2, 16);
        let a = decode_estimate(
            &shape, &cluster, ParallelismConfig::tp(tp), &tcfg, ctx, resp,
        );
        let b = decode_estimate(
            &shape, &cluster, ParallelismConfig::tp(tp), &tcfg, ctx * 2, resp,
        );
        if let (Some(a), Some(b)) = (a, b) {
            assert!(
                b.tgs <= a.tgs * 1.0001,
                "TGS rose with context: {} -> {} (TP{tp}, resp {resp}, ctx {ctx})",
                a.tgs,
                b.tgs
            );
        }
    });
}

#[test]
fn prop_range_table_lookup_total_and_monotone() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    let tcfg = ThroughputCfg::default();
    check_default("range_table_lookup", |rng| {
        let responses = *rng.choose(&[32usize, 64, 128]);
        let ctx_grid = [2048usize, 4096, 8192, 16384, 32768];
        let points: Vec<ProfilePoint<usize>> = ctx_grid
            .iter()
            .flat_map(|&ctx| [2usize, 4, 8].map(move |tp| (ctx, tp)))
            .map(|(ctx, tp)| ProfilePoint {
                config: tp,
                ctx,
                tgs: decode_estimate(
                    &shape,
                    &cluster,
                    ParallelismConfig::tp(tp),
                    &tcfg,
                    ctx,
                    responses,
                )
                .map(|e| e.tgs),
            })
            .collect();
        let table = RangeTable::from_profile(&points).expect("feasible");
        // Total: any query — including far outside the profiled grid —
        // lands on an entry, and the entry's bound covers the query
        // whenever any profiled bound does.
        let ctx = 1 + rng.below(48 * 1024);
        let (bound, _, tgs) = table.lookup(ctx);
        if ctx <= table.max_bound() {
            assert!(bound >= ctx, "bound {bound} below query {ctx}");
        } else {
            assert_eq!(bound, table.max_bound(), "overflow must clamp");
        }
        assert!(tgs > 0.0, "selected entry carries no throughput");
        // Monotone: a longer context never maps to an earlier range.
        let longer = ctx + rng.below(16 * 1024);
        assert!(
            table.lookup(longer).0 >= bound,
            "lookup bound regressed: {ctx} -> {bound}, {longer} -> {}",
            table.lookup(longer).0
        );
    });
}

#[test]
fn prop_fit_sequences_monotone() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    check_default("fit_sequences_monotone", |rng| {
        let tp = *rng.choose(&[1usize, 2, 4]);
        let ctx = 1024 * gen::usize_in(rng, 1, 48);
        let resp = 8 * gen::usize_in(rng, 1, 32);
        let cfg = ParallelismConfig::tp(tp);
        let fit = fit_sequences(&shape, cfg, &cluster.gpu, ctx, resp);
        // More context can only shrink the resident batch.
        assert!(
            fit_sequences(&shape, cfg, &cluster.gpu, ctx * 2, resp) <= fit,
            "fit rose with context (TP{tp}, ctx {ctx}, resp {resp})"
        );
        // More tensor parallelism can only grow it: weights shard down
        // and per-sequence KV shards down.
        let wider = ParallelismConfig::tp(tp * 2);
        assert!(
            fit_sequences(&shape, wider, &cluster.gpu, ctx, resp) >= fit,
            "fit fell with TP (TP{tp}, ctx {ctx}, resp {resp})"
        );
    });
}

#[test]
fn prop_rollout_oom_monotone() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    check_default("rollout_oom_monotone", |rng| {
        let tp = *rng.choose(&[1usize, 2, 4]);
        let ctx = 1024 * gen::usize_in(rng, 1, 48);
        let resp = 8 * gen::usize_in(rng, 1, 32);
        let cfg = ParallelismConfig::tp(tp);
        if rollout_oom(&shape, cfg, &cluster.gpu, ctx, resp) {
            // A config dead at some context stays dead at any longer one.
            assert!(
                rollout_oom(&shape, cfg, &cluster.gpu, ctx * 2, resp),
                "OOM not monotone in ctx (TP{tp}, ctx {ctx}, resp {resp})"
            );
        } else {
            // A config alive at TP t stays alive at TP 2t.
            assert!(
                !rollout_oom(
                    &shape,
                    ParallelismConfig::tp(tp * 2),
                    &cluster.gpu,
                    ctx,
                    resp
                ),
                "OOM not anti-monotone in TP (TP{tp}, ctx {ctx}, resp {resp})"
            );
        }
    });
}

#[test]
fn prop_watermark_crosses_one_exactly_at_oom() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    check_default("watermark_oom_equiv", |rng| {
        let tp = *rng.choose(&[1usize, 2, 4, 8]);
        let ctx = 1024 * gen::usize_in(rng, 1, 64);
        // Multiples of 8 keep the min-live batch integral, which is
        // where the doc-promised "crosses 1.0 exactly at the OOM flip"
        // equivalence is exact (fractional min-live rounds inside the
        // integer fit but not inside the watermark).
        let resp = 8 * gen::usize_in(rng, 1, 32);
        let cfg = ParallelismConfig::tp(tp);
        let wm = rollout_watermark_frac(&shape, cfg, &cluster.gpu, ctx, resp);
        let oom = rollout_oom(&shape, cfg, &cluster.gpu, ctx, resp);
        if wm < 1.0 - 1e-9 {
            assert!(!oom, "watermark {wm} < 1 but OOM (TP{tp}, ctx {ctx}, resp {resp})");
        }
        if wm > 1.0 + 1e-9 {
            assert!(oom, "watermark {wm} > 1 but fits (TP{tp}, ctx {ctx}, resp {resp})");
        }
    });
}

#[test]
fn prop_replanner_is_deterministic() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    let tcfg = ThroughputCfg::default();
    check_default("replanner_deterministic", |rng| {
        let responses = *rng.choose(&[32usize, 64, 128]);
        let mut a = Replanner::new(shape, cluster.clone(), tcfg, responses, 4096)
            .expect("plannable");
        let mut b = Replanner::new(shape, cluster.clone(), tcfg, responses, 4096)
            .expect("plannable");
        // Same observed-signal stream => bit-identical decision stream,
        // whatever the stream is. This is what makes a re-planned run
        // reproducible from its metrics log.
        for _ in 0..gen::usize_in(rng, 1, 12) {
            let mean = 1024.0 * gen::usize_in(rng, 2, 48) as f64;
            let s = ReplanSignals {
                ctx_mean: mean,
                ctx_p95: mean * 1.2,
                ctx_max: mean * 1.3,
                dispatch_bytes: rng.next_u64() % (1 << 24),
                dispatch_controller_bytes: 1 << 10,
                rollout_seconds: *rng.choose(&[0.5, 2.0]),
                train_seconds: 1.0,
            };
            let da = a.decide(&s, false);
            let db = b.decide(&s, false);
            assert_eq!(da.label(), db.label());
            assert_eq!(da.switched(), db.switched());
            assert_eq!(da.planning_ctx, db.planning_ctx);
            assert_eq!(da.memory_forced, db.memory_forced);
            assert_eq!(da.mem_watermark_frac, db.mem_watermark_frac);
        }
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.peak_watermark, b.peak_watermark);
        assert_eq!(a.rollout_config(), b.rollout_config());
        assert_eq!(a.train_config(), b.train_config());
    });
}

// ---------------------------------------------------------------------------
// Environment invariants
// ---------------------------------------------------------------------------

fn random_playout(rng: &mut Pcg64, game: &mut dyn Game) -> Outcome {
    loop {
        if let Some(o) = game.outcome() {
            return o;
        }
        let legal = game.legal_actions();
        assert!(!legal.is_empty(), "non-terminal game with no moves");
        game.play(*rng.choose(&legal));
    }
}

#[test]
fn prop_games_terminate_with_consistent_state() {
    check_default("game_invariants", |rng| {
        let mut game: Box<dyn Game> = if rng.below(2) == 0 {
            Box::new(TicTacToe::new())
        } else {
            Box::new(ConnectFour::new())
        };
        let max_moves = game.num_actions() * 7; // 9*7 / 7*7 upper bounds
        let mut moves = 0;
        while game.outcome().is_none() {
            let legal = game.legal_actions();
            // Legal actions are unique, in range, and actually legal.
            let mut sorted = legal.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), legal.len());
            assert!(legal.iter().all(|&a| a < game.num_actions()));
            assert!(legal.iter().all(|&a| game.is_legal(a)));
            let side = game.to_move();
            game.play(*rng.choose(&legal));
            assert_ne!(game.to_move(), side, "side must alternate");
            moves += 1;
            assert!(moves <= max_moves, "game failed to terminate");
        }
        // Terminal: no legal moves, outcome stable.
        assert!(game.legal_actions().is_empty());
        assert_eq!(game.outcome(), game.outcome());
    });
}

#[test]
fn prop_clone_game_is_deep() {
    check_default("clone_deep", |rng| {
        let mut game = TicTacToe::new();
        for _ in 0..gen::usize_in(rng, 0, 4) {
            let legal = game.legal_actions();
            if legal.is_empty() {
                break;
            }
            game.play(*rng.choose(&legal));
        }
        let snapshot = game.clone_game();
        let before: Vec<usize> = snapshot.legal_actions();
        // Mutate the original; the clone must not change.
        if game.outcome().is_none() {
            if let Some(&a) = game.legal_actions().first() {
                game.play(a);
            }
        }
        assert_eq!(snapshot.legal_actions(), before);
        let _ = random_playout(rng, &mut game);
    });
}

// ---------------------------------------------------------------------------
// RL / advantage invariants
// ---------------------------------------------------------------------------

fn synth_episode(rng: &mut Pcg64, n_turns: usize, reward: f32) -> Episode {
    let mut tokens = vec![tok::BOS];
    let mut mask = vec![0.0f32];
    let mut turns = Vec::new();
    for _ in 0..n_turns {
        let prompt_start = tokens.len();
        tokens.extend([tok::ENV, tok::CELL_EMPTY, tok::SEP, tok::AGENT]);
        mask.extend([0.0; 4]);
        let response_start = tokens.len();
        for _ in 0..gen::usize_in(rng, 0, 3) {
            tokens.push(tok::THINK_BASE + rng.below(8) as i32);
            mask.push(1.0);
        }
        tokens.push(tok::move_token(rng.below(9)));
        mask.push(1.0);
        turns.push(Turn {
            prompt_start,
            response_start,
            response_end: tokens.len(),
            action: None,
            behavior_logprob: -(rng.f64() as f32),
        });
    }
    Episode {
        tokens,
        action_mask: mask,
        turns,
        status: EpisodeStatus::Finished,
        reward,
    }
}

#[test]
fn prop_synthetic_episodes_validate() {
    check_default("episode_validate", |rng| {
        let n_turns = gen::usize_in(rng, 1, 6);
        let ep = synth_episode(rng, n_turns, 1.0);
        ep.validate().unwrap();
        // Episode context = BOS + sum of turn extents (turns abut).
        let turn_total: usize = ep.turns.iter().map(|t| t.context_len()).sum();
        assert_eq!(ep.context_len(), 1 + turn_total);
    });
}

#[test]
fn prop_whiten_statistics() {
    check_default("whiten_stats", |rng| {
        let mut xs: Vec<f32> =
            gen::vec_of(rng, 2, 64, |r| (r.gaussian() * 3.0) as f32);
        // Ensure non-constant.
        xs[0] += 1.0;
        let orig = xs.clone();
        whiten(&mut xs);
        let n = xs.len() as f32;
        let mean: f32 = xs.iter().sum::<f32>() / n;
        assert!(mean.abs() < 1e-4, "mean {mean}");
        // Order preserved.
        for i in 0..xs.len() {
            for j in 0..xs.len() {
                if orig[i] < orig[j] {
                    assert!(xs[i] <= xs[j] + 1e-5);
                }
            }
        }
    });
}

#[test]
fn prop_advantages_rank_by_outcome() {
    check_default("advantage_ranking", |rng| {
        let n = gen::usize_in(rng, 3, 16);
        let rewards: Vec<f32> =
            (0..n).map(|_| *rng.choose(&[-1.0f32, 0.0, 1.0])).collect();
        let eps: Vec<Episode> = rewards
            .iter()
            .map(|&r| synth_episode(rng, 2, r))
            .collect();
        let mut batch = ExperienceBatch::new(eps);
        reinforce_advantages(
            &mut batch,
            AdvantageCfg { gamma: 1.0, whiten: true, ..AdvantageCfg::default() },
        );
        for i in 0..n {
            for j in 0..n {
                if rewards[i] < rewards[j] {
                    assert!(
                        batch.advantages[i] <= batch.advantages[j] + 1e-5,
                        "adv ranking violated"
                    );
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// JSON substrate (round-trip under random values)
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.gaussian() * 1e3).round()),
        3 => Json::Str(
            (0..rng.below(12))
                .map(|_| *rng.choose(&['a', 'b', '\\', '"', 'x', '\n', '7']))
                .collect(),
        ),
        4 => Json::Arr(
            (0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    check_default("json_roundtrip", |rng| {
        let v = random_json(rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| {
            panic!("reparse failed for {s:?}: {e}");
        });
        assert_eq!(back, v, "roundtrip mismatch for {s:?}");
    });
}
