#![cfg(loom)]
//! Loom model checks for the crate's two concurrency cores. These
//! explore every interleaving *below* the mutex level (lock handoffs,
//! condvar wakeups) — the layer the sequential interleaving models in
//! `tests/model_concurrency.rs` take on faith.
//!
//! The offline build image cannot vendor the `loom` crate, so this
//! file is compiled out of every normal build (`--cfg loom` is never
//! set; `Cargo.toml` declares the cfg for the lint). To run the
//! models on a networked machine:
//!
//! ```sh
//! cd rust
//! cargo add loom@0.7 --dev          # one-time, not committed
//! RUSTFLAGS="--cfg loom" cargo test --release \
//!     --no-default-features --test loom_model
//! ```

use loom::sync::Arc;
use loom::thread;

use earl::dispatch::tcp::IngestState;
use earl::dispatch::wire::{ReceivedBatch, ShardDesc, WireDtype, WireTensorId};
use earl::runtime::snapshot::StepBuffer;

fn one_row(tensor: WireTensorId, row_bytes: u32, row: u32) -> ReceivedBatch {
    let mut b = ReceivedBatch::new();
    let desc = ShardDesc::raw(tensor, WireDtype::I32, row, 1, row_bytes);
    b.insert(&desc, &vec![0xAB; row_bytes as usize]).unwrap();
    b
}

/// Publish/acquire monotonicity: concurrent publishers never regress
/// the front, concurrent readers observe a monotone step sequence, and
/// every interleaving converges to the newest step.
#[test]
fn step_buffer_publish_acquire_monotone() {
    loom::model(|| {
        let buf = Arc::new(StepBuffer::new());
        let p1 = {
            let b = Arc::clone(&buf);
            // May lose the race against step 2 — that is the monotone
            // rejection, not an error.
            thread::spawn(move || {
                let _ = b.publish(1, 10u64);
            })
        };
        let p2 = {
            let b = Arc::clone(&buf);
            thread::spawn(move || b.publish(2, 20u64).unwrap())
        };
        let reader = {
            let b = Arc::clone(&buf);
            thread::spawn(move || {
                let a = b.front_step();
                let c = b.front_step();
                assert!(a <= c, "reader saw front regress {a:?} -> {c:?}");
            })
        };
        p1.join().unwrap();
        p2.join().unwrap();
        reader.join().unwrap();
        // Step 2 always wins; its value is never torn.
        assert_eq!(buf.front_step(), Some(2));
        assert_eq!(*buf.front().unwrap(), 20);
        // The condvar path: an acquire bounded at the newest step is
        // satisfied without further publishes.
        let v = buf
            .acquire(2, std::time::Duration::from_secs(3600))
            .unwrap();
        assert_eq!(*v, 20);
    });
}

/// `IngestState::merge` all-or-nothing under every lock interleaving:
/// compatible frames from two senders always union; a conflicting
/// frame fails whichever side loses the race AND discards the whole
/// epoch (no half-merged batch survives for a later commit).
#[test]
fn ingest_state_merge_all_or_nothing() {
    use WireTensorId::{Mask, Tokens};

    // Compatible senders: both merges land, any order.
    loom::model(|| {
        let st = Arc::new(IngestState::new());
        let a = {
            let s = Arc::clone(&st);
            thread::spawn(move || s.merge(7, one_row(Tokens, 8, 0)).unwrap())
        };
        let b = {
            let s = Arc::clone(&st);
            thread::spawn(move || s.merge(7, one_row(Mask, 4, 0)).unwrap())
        };
        a.join().unwrap();
        b.join().unwrap();
        let batch = st.take(7).unwrap();
        assert!(batch.tensor(Tokens).is_some());
        assert!(batch.tensor(Mask).is_some());
    });

    // Conflicting senders: the first to the lock wins, the second
    // errors and drops the epoch — the final state is always empty.
    loom::model(|| {
        let st = Arc::new(IngestState::new());
        let a = {
            let s = Arc::clone(&st);
            thread::spawn(move || s.merge(7, one_row(Tokens, 8, 0)).is_ok())
        };
        let b = {
            let s = Arc::clone(&st);
            thread::spawn(move || s.merge(7, one_row(Tokens, 4, 1)).is_ok())
        };
        let ok_a = a.join().unwrap();
        let ok_b = b.join().unwrap();
        assert!(
            ok_a ^ ok_b,
            "exactly one merge wins the race (a: {ok_a}, b: {ok_b})"
        );
        assert!(
            st.take(7).unwrap().is_empty(),
            "conflict retained a half-merged epoch"
        );
    });
}
