//! Integration: the Data Dispatcher end to end — plans executed on the
//! network simulator AND on real TCP loopback, with content/latency
//! cross-checks between the two engines and against the paper's Fig. 4
//! expectations.

use earl::cluster::ClusterSpec;
use earl::dispatch::{
    plan_alltoall, plan_centralized, satisfies, simulate_plan,
    tcp::execute_plan_tcp_rated, DataLayout, WorkerMap,
};

const N: usize = 8;

fn layouts() -> (DataLayout, DataLayout) {
    let items = N * N;
    (
        DataLayout::round_robin(items, N),
        DataLayout::blocked(items, N),
    )
}

#[test]
fn sim_and_tcp_agree_on_winner() {
    let (p, c) = layouts();
    let shard = 256 << 10; // keep the test fast
    let base = plan_centralized(&p, &c, shard, 0);
    let earl = plan_alltoall(&p, &c, shard);

    let cluster = ClusterSpec::paper_testbed();
    let map = WorkerMap::one_per_node(&cluster, N);
    let sim_base = simulate_plan(&cluster, &map, &base).makespan;
    let sim_earl = simulate_plan(&cluster, &map, &earl).makespan;

    let nic = Some(100e6); // 100 MB/s emulated NIC keeps this quick
    let tcp_base = execute_plan_tcp_rated(&base, N, nic).unwrap().seconds;
    let tcp_earl = execute_plan_tcp_rated(&earl, N, nic).unwrap().seconds;

    assert!(sim_base > sim_earl, "simulator: baseline must be slower");
    assert!(tcp_base > tcp_earl, "tcp: baseline must be slower");
    // Both engines should see a substantial (>3x) reduction at 8 workers.
    assert!(sim_base / sim_earl > 3.0, "sim ratio {}", sim_base / sim_earl);
    assert!(tcp_base / tcp_earl > 3.0, "tcp ratio {}", tcp_base / tcp_earl);
}

#[test]
fn tcp_rated_latency_tracks_bytes() {
    // Double the bytes -> roughly double the (rated) latency.
    let (p, c) = layouts();
    let nic = Some(100e6);
    let small = plan_alltoall(&p, &c, 512 << 10);
    let large = plan_alltoall(&p, &c, 1 << 20);
    let ts = execute_plan_tcp_rated(&small, N, nic).unwrap().seconds;
    let tl = execute_plan_tcp_rated(&large, N, nic).unwrap().seconds;
    let ratio = tl / ts;
    assert!(
        ratio > 1.4 && ratio < 2.8,
        "latency should ~double with bytes: {ratio:.2}"
    );
}

#[test]
fn plans_identical_placement_across_engines() {
    let (p, c) = layouts();
    let base = plan_centralized(&p, &c, 1000, 0);
    let earl = plan_alltoall(&p, &c, 1000);
    assert!(satisfies(&base, &p, &c));
    assert!(satisfies(&earl, &p, &c));
    assert_eq!(base.delivered(&p), earl.delivered(&p));
}

#[test]
fn controller_choice_does_not_change_content() {
    let (p, c) = layouts();
    for controller in 0..N {
        let plan = plan_centralized(&p, &c, 500, controller);
        assert!(satisfies(&plan, &p, &c), "controller {controller}");
    }
}

#[test]
fn simulator_reduction_in_paper_band_at_full_scale() {
    // Full 46–187 MiB shards on the simulator (fast — no real bytes).
    let cluster = ClusterSpec::paper_testbed();
    let map = WorkerMap::one_per_node(&cluster, N);
    let (p, c) = layouts();
    let mut prev_ratio = 0.0;
    for mib in [46u64, 93, 187] {
        let item = mib * (1 << 20) / N as u64;
        let base = plan_centralized(&p, &c, item, 0);
        let earl = plan_alltoall(&p, &c, item);
        let tb = simulate_plan(&cluster, &map, &base).makespan;
        let te = simulate_plan(&cluster, &map, &earl).makespan;
        let ratio = tb / te;
        assert!(
            ratio > 6.0 && ratio < 20.0,
            "{mib} MiB: ratio {ratio:.1} outside Fig. 4 band"
        );
        assert!(ratio >= prev_ratio * 0.95, "ratio should not shrink");
        prev_ratio = ratio;
    }
}
