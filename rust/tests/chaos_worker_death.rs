//! Chaos: worker death mid-run against a 3-process ingest fleet.
//!
//! The coordinator must treat worker death as a *recoverable* event:
//! each killed worker's rows are re-planned onto the survivors
//! (`replan_ingest_excluding`) and the learning curve stays
//! **bit-identical** to the serial reference — fault tolerance is a
//! systems property, not a training change. Only the loss of the whole
//! fleet is an error, and a deterministic one: the model is untouched.
//!
//! Also pins the tentpole efficiency claim of the tree merge: with a
//! merge schedule attached, the coordinator receives exactly **one**
//! root report per step (O(log n) reduction depth on the workers)
//! instead of one per worker, and the root is bit-identical to the
//! star/serial fold because `merge_reports` uses the same fixed
//! recursive-halving tree over the logical worker list.
//!
//! Runs without the `xla` feature (CI job `core-no-xla`,
//! `make check-core`).

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use earl::coordinator::{IngestCfg, IngestCoordinator};
use earl::dispatch::merge_tree_depth;

/// A spawned `earl worker --ingest` process, killed on drop even if the
/// test panics first.
struct WorkerProc {
    child: Child,
    addr: SocketAddr,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl WorkerProc {
    fn kill(&mut self) {
        self.child.kill().unwrap();
        self.child.wait().unwrap();
    }
}

fn spawn_ingest_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_earl"))
        .args(["worker", "--listen", "127.0.0.1:0", "--ingest", "--quiet"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning earl worker --ingest");
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .and_then(|a| a.parse().ok())
        .unwrap_or_else(|| panic!("unparseable worker banner {line:?}"));
    WorkerProc { child, addr }
}

fn cfg() -> IngestCfg {
    IngestCfg {
        n_workers: 3,
        rows: 9,
        seq: 24,
        vocab: 16,
        seed: 11,
        commit_timeout: Duration::from_secs(60),
        ..IngestCfg::default()
    }
}

#[test]
fn killing_workers_mid_run_keeps_the_curve_bit_identical() {
    const STEPS: usize = 6;
    let cfg = cfg();

    // Serial reference for the whole trajectory.
    let mut serial = IngestCoordinator::local(cfg.clone()).unwrap();
    let mut reference = Vec::new();
    for _ in 0..STEPS {
        reference.push(serial.step().unwrap());
    }

    let mut workers: Vec<WorkerProc> =
        (0..3).map(|_| spawn_ingest_worker()).collect();
    let addrs: Vec<SocketAddr> = workers.iter().map(|w| w.addr).collect();
    let mut coord = IngestCoordinator::connect(cfg.clone(), addrs).unwrap();

    let t0 = Instant::now();
    for (k, want) in reference.iter().enumerate() {
        // Kill schedule: worker 2 dies before step 2, worker 1 before
        // step 4 — the final steps run on a single survivor carrying
        // all three logical workers' rows.
        if k == 2 {
            workers[2].kill();
        }
        if k == 4 {
            workers[1].kill();
        }
        let got = coord.step().unwrap_or_else(|e| {
            panic!("chaos step {k} failed to recover: {e:#}")
        });
        assert_eq!(
            got.training_row(),
            want.training_row(),
            "chaos step {k} diverged from the serial reference"
        );
        if k == 2 || k == 4 {
            assert!(
                got.redispatches >= 1,
                "kill step {k} recovered without recording a re-dispatch"
            );
        }
        // Tentpole claim: the tree merge delivers exactly one root
        // report per step — O(log n) reduction depth on the workers —
        // instead of one report per worker (the star merge).
        assert_eq!(
            got.reports_received, 1,
            "step {k} fell back to the star merge"
        );
        assert_eq!(got.merge_depth, merge_tree_depth(cfg.n_workers));
        assert!(
            (got.reports_received as usize) < cfg.n_workers,
            "coordinator-received reports must shrink below O(workers)"
        );
    }
    assert!(
        t0.elapsed() < Duration::from_secs(240),
        "chaos recovery must not hang"
    );
    // The models agree exactly — same parameters, bit for bit — and
    // every step's merged worker metrics account for every row.
    assert_eq!(coord.model, serial.model);
    assert_eq!(coord.model.step, STEPS as u64);
    for (step, m) in coord.metrics.worker_steps.iter() {
        assert_eq!(m.rows, cfg.rows as u64, "step {step} lost worker rows");
    }

    // Kill the last survivor: the step fails deterministically, fast,
    // and the model is untouched.
    let params_before = coord.model.w.clone();
    let step_before = coord.model.step;
    workers[0].kill();
    let t1 = Instant::now();
    let err = coord.step().unwrap_err();
    assert!(
        format!("{err:#}").contains("dead")
            || format!("{err:#}").contains("worker"),
        "unexpected total-loss error: {err:#}"
    );
    assert!(
        t1.elapsed() < Duration::from_secs(60),
        "total-loss failure must surface promptly"
    );
    assert_eq!(coord.model.step, step_before);
    assert_eq!(coord.model.w, params_before);
}
