//! Interleaving model checks for the two concurrency cores: the
//! bounded-staleness [`StepBuffer`] and the dispatcher's `IngestState`.
//!
//! Both structures serialize every operation behind one coarse mutex,
//! so any real concurrent execution is equivalent to *some* sequential
//! interleaving of the operations — which
//! [`earl::testkit::interleave::explore`] enumerates exhaustively.
//! Each schedule replays the per-thread scripts against the real
//! structure and checks the invariant against an independently-computed
//! model. The `cfg(loom)` models in `tests/loom_model.rs` cover the
//! same invariants below the mutex level; this suite runs always
//! (including `--no-default-features`, so it is part of the TSan job).

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use earl::dispatch::tcp::{IngestState, MAX_PENDING_INGEST_EPOCHS};
use earl::dispatch::wire::{ReceivedBatch, ShardDesc, WireDtype, WireTensorId};
use earl::runtime::snapshot::StepBuffer;
use earl::testkit::interleave::{explore, schedule_count};

// ---------------------------------------------------------------------------
// StepBuffer: publish/front monotonicity under every interleaving
// ---------------------------------------------------------------------------

#[test]
fn step_buffer_front_is_monotone_under_all_interleavings() {
    // Three publishers with overlapping step ranges; 210 schedules.
    let scripts: [&[u64]; 3] = [&[1, 2, 5], &[2, 4], &[3, 3]];
    let counts: Vec<usize> = scripts.iter().map(|s| s.len()).collect();

    let got = explore(&counts, 10_000, |schedule| {
        let buf = StepBuffer::new();
        let mut idx = [0usize; 3];
        let mut last_front: Option<u64> = None;
        for &t in schedule {
            let step = scripts[t][idx[t]];
            idx[t] += 1;
            let before = buf.front_step();
            let res = buf.publish(step, step);
            // Publish succeeds exactly when it does not regress the
            // front, and on success the front *is* the published step.
            let expect_ok = before.map_or(true, |cur| step >= cur);
            assert_eq!(
                res.is_ok(),
                expect_ok,
                "publish({step}) with front {before:?} in {schedule:?}"
            );
            let after = buf.front_step();
            if expect_ok {
                assert_eq!(after, Some(step));
            } else {
                assert_eq!(after, before, "failed publish moved the front");
            }
            // Global monotonicity: the front never goes backwards.
            assert!(
                after >= last_front,
                "front regressed {last_front:?} -> {after:?} in {schedule:?}"
            );
            last_front = after;
            // Arc handout coherence: the value is the step it was
            // stamped with (readers can never see a torn pair).
            let v = buf.front().expect("published");
            assert_eq!(Some(*v), after);
        }
        // 5 is the maximum step across all scripts, so it is always
        // accepted and nothing after it can win: every interleaving
        // converges to the same front.
        assert_eq!(buf.front_step(), Some(5));
        // Bounded-staleness acquire sees it without blocking.
        let v = buf.acquire(5, Duration::from_millis(50)).expect("fresh");
        assert_eq!(*v, 5);
    });
    assert!(!got.truncated, "exploration must be exhaustive");
    assert_eq!(got.schedules as u64, schedule_count(&counts));
}

#[test]
fn step_buffer_acquire_rejects_stale_and_times_out() {
    let buf = StepBuffer::new();
    buf.publish(3, 30u64).expect("publish");
    // Satisfiable bound: returns immediately.
    assert_eq!(*buf.acquire(2, Duration::from_millis(50)).expect("ok"), 30);
    // Unsatisfiable bound: errors after the timeout instead of handing
    // out a staler-than-requested value.
    let err = buf.acquire(4, Duration::from_millis(40));
    assert!(err.is_err(), "acquire handed out a stale value");
    assert_eq!(buf.front_step(), Some(3));
}

// ---------------------------------------------------------------------------
// IngestState: all-or-nothing epoch merges under every interleaving
// ---------------------------------------------------------------------------

/// One single-row shard: `(tensor, row_bytes, row index)`.
type Shard = (WireTensorId, u32, u32);

fn batch_of(shards: &[Shard]) -> ReceivedBatch {
    let mut b = ReceivedBatch::new();
    for &(tensor, row_bytes, row) in shards {
        let desc = ShardDesc::raw(tensor, WireDtype::I32, row, 1, row_bytes);
        b.insert(&desc, &vec![0xAB; row_bytes as usize])
            .expect("self-consistent test batch");
    }
    b
}

/// Pure mirror of the epoch-level all-or-nothing contract: a merge
/// whose shards conflict with the retained entry (same tensor,
/// different row size) fails AND discards the whole epoch; a successful
/// merge is the union.
type Model = BTreeMap<u16, (u32, BTreeSet<u32>)>;

fn model_merge(entry: &mut Option<Model>, shards: &[Shard]) -> bool {
    let mut work = entry.take().unwrap_or_default();
    for &(tensor, row_bytes, row) in shards {
        let e = work.entry(tensor.code()).or_insert((row_bytes, BTreeSet::new()));
        if e.0 != row_bytes {
            return false; // entry stays None: epoch discarded
        }
        e.1.insert(row);
    }
    *entry = Some(work);
    true
}

#[test]
fn ingest_merge_is_all_or_nothing_under_all_interleavings() {
    use WireTensorId::{Mask, Tokens};
    // Sender A streams two well-formed Tokens frames; sender B first
    // sends a conflicting Tokens shape (a corrupted/mismatched peer),
    // then a clean Mask frame. Depending on order, either side can be
    // the one that conflicts — and a conflict must drop the *whole*
    // epoch, never retain a half-merged batch.
    let scripts: [&[&[Shard]]; 2] = [
        &[&[(Tokens, 8, 0)], &[(Tokens, 8, 1)]],
        &[&[(Tokens, 4, 2)], &[(Mask, 4, 0)]],
    ];
    let counts: Vec<usize> = scripts.iter().map(|s| s.len()).collect();

    let got = explore(&counts, 1_000, |schedule| {
        let state = IngestState::new();
        let mut model: Option<Model> = None;
        let mut idx = [0usize; 2];
        for &t in schedule {
            let shards = scripts[t][idx[t]];
            idx[t] += 1;
            let expect_ok = model_merge(&mut model, shards);
            let res = state.merge(7, batch_of(shards));
            assert_eq!(
                res.is_ok(),
                expect_ok,
                "merge {shards:?} in {schedule:?}: {res:?}"
            );
        }
        // The final reassembled batch must be exactly the model's union
        // of fully-applied frames — nothing partial, nothing extra.
        let batch = state.take(7).expect("not poisoned");
        match model {
            None => assert!(batch.is_empty(), "conflict retained partial state"),
            Some(m) => {
                assert_eq!(batch.tensors().count(), m.len());
                for (code, (row_bytes, rows)) in m {
                    let id = WireTensorId::from_code(code).expect("model code");
                    let t = batch.tensor(id).expect("model tensor present");
                    assert_eq!(t.row_bytes as u32, row_bytes);
                    let present: BTreeSet<u32> = (0..t.present.len() as u32)
                        .filter(|&r| t.row(r as usize).is_some())
                        .collect();
                    assert_eq!(present, rows, "rows of {id:?} in {schedule:?}");
                }
            }
        }
        // take() consumed the epoch.
        assert_eq!(state.pending_epochs(), 0);
    });
    assert!(!got.truncated);
    assert_eq!(got.schedules as u64, schedule_count(&counts));
}

#[test]
fn ingest_eviction_caps_pending_epochs() {
    use WireTensorId::Tokens;
    let state = IngestState::new();
    let total = MAX_PENDING_INGEST_EPOCHS as u64 + 5;
    for epoch in 0..total {
        state
            .merge(epoch, batch_of(&[(Tokens, 8, 0)]))
            .expect("clean merge");
        assert!(
            state.pending_epochs() <= MAX_PENDING_INGEST_EPOCHS,
            "pending epochs exceeded the cap at epoch {epoch}"
        );
    }
    assert_eq!(state.pending_epochs(), MAX_PENDING_INGEST_EPOCHS);
    // The oldest epochs were evicted (never committed, sender stalled).
    assert!(state.take(0).expect("not poisoned").is_empty());
    // Taking an epoch prunes every older leftover but keeps newer ones.
    let newest_kept = total - 1;
    let mid = total - 3;
    assert!(!state.take(mid).expect("not poisoned").is_empty());
    assert_eq!(state.pending_epochs(), (newest_kept - mid) as usize);
    assert!(!state.take(newest_kept).expect("not poisoned").is_empty());
    assert_eq!(state.pending_epochs(), 0);
}

#[test]
fn ingest_eviction_spares_epochs_with_live_connections() {
    use WireTensorId::Tokens;
    let state = IngestState::new();
    // Connection 42 feeds epoch 0, then goes quiet (e.g. a slow commit
    // during a coordinator re-plan) while anonymous senders pile up
    // MAX_PENDING_INGEST_EPOCHS of pressure. The live epoch must ride
    // out the cap instead of being evicted under its connection.
    state
        .merge_from(0, batch_of(&[(Tokens, 8, 0)]), Some(42))
        .expect("clean merge");
    let total = MAX_PENDING_INGEST_EPOCHS as u64 + 6;
    for epoch in 1..total {
        state
            .merge(epoch, batch_of(&[(Tokens, 8, 0)]))
            .expect("clean merge");
        assert!(
            !state
                .commit_batch(0)
                .expect("live epoch evicted under pressure")
                .is_empty(),
            "live epoch emptied at pressure epoch {epoch}"
        );
        // The cap still bounds memory: only the protected epoch may
        // exceed it.
        assert!(state.pending_epochs() <= MAX_PENDING_INGEST_EPOCHS + 1);
    }
    // Once its connection closes, the epoch loses protection and the
    // next merge's eviction sweep reclaims it.
    state.conn_closed(42);
    state
        .merge(total, batch_of(&[(Tokens, 8, 0)]))
        .expect("clean merge");
    assert!(
        state.commit_batch(0).is_err() || state.commit_batch(0).unwrap().is_empty(),
        "unprotected stale epoch survived the eviction sweep"
    );
    assert!(state.pending_epochs() <= MAX_PENDING_INGEST_EPOCHS);
}

// ---------------------------------------------------------------------------
// Real-thread stress (the schedule the enumerator abstracts): this is
// the test the nightly ThreadSanitizer job leans on.
// ---------------------------------------------------------------------------

#[test]
fn step_buffer_threaded_readers_observe_monotone_fronts() {
    let buf = std::sync::Arc::new(StepBuffer::new());
    let mut handles = Vec::new();
    for p in 0..2u64 {
        let b = std::sync::Arc::clone(&buf);
        handles.push(std::thread::spawn(move || {
            for s in 0..50u64 {
                // Interleaved step sequences; regressions are expected
                // losses of the publish race, never panics.
                let _ = b.publish(s * 2 + p, s * 2 + p);
            }
        }));
    }
    let reader = {
        let b = std::sync::Arc::clone(&buf);
        std::thread::spawn(move || {
            let mut last = None;
            for _ in 0..200 {
                let now = b.front_step();
                assert!(now >= last, "front regressed {last:?} -> {now:?}");
                last = now;
                std::thread::yield_now();
            }
        })
    };
    for h in handles {
        h.join().expect("publisher");
    }
    reader.join().expect("reader");
    // Highest step overall is 99 (publisher 1, s=49).
    assert_eq!(buf.front_step(), Some(99));
    assert_eq!(*buf.acquire(99, Duration::from_secs(1)).expect("fresh"), 99);
}
