//! Integration: the pipelined step engine — Overlapped mode must
//! reproduce Serial-mode training metrics for a fixed seed (the overlap
//! is a pure systems change), the three-stage `OverlappedAsync` engine
//! must reproduce them at `max_staleness = 0` and stay within its
//! staleness bound otherwise, the shared `SnapshotBuffer` must stay
//! monotone under concurrent publishing, and the persistent TCP
//! dispatch runtime must execute arbitrary-phase plans while reusing
//! connections across steps.

#![cfg(feature = "xla")]

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use earl::config::TrainConfig;
use earl::coordinator::{
    DispatchJob, DispatchMode, DispatchWorker, PipelineMode, Trainer,
};
use earl::dispatch::{
    plan_alltoall, Codec, DataLayout, DispatchPlan, TcpRuntime,
    WorkerTransfer,
};
use earl::metrics::StepRecord;
use earl::runtime::{ModelState, SnapshotBuffer};
use earl::util::threadpool::ThreadPool;
use xla::Literal;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Box::leak(dir.into_boxed_path()))
    } else {
        eprintln!("artifacts/ missing — run `make artifacts`; skipping");
        None
    }
}

fn run_mode_stale(
    dir: &Path,
    mode: PipelineMode,
    max_staleness: u64,
) -> Vec<StepRecord> {
    let cfg = TrainConfig {
        artifacts_dir: dir.to_path_buf(),
        steps: 5,
        seed: 42,
        pipeline: mode,
        max_staleness,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    t.metrics.records.clone()
}

fn run_mode(dir: &Path, mode: PipelineMode) -> Vec<StepRecord> {
    run_mode_stale(dir, mode, 1)
}

/// Training metrics (not timings) of a record, for cross-mode equality.
fn metric_row(r: &StepRecord) -> (u64, f64, f64, f64, f64, f64, f64, usize, bool) {
    (
        r.step,
        r.mean_return,
        r.mean_episode_ctx,
        r.mean_turn_ctx,
        r.loss,
        r.kl,
        r.entropy,
        r.bucket,
        r.selector_switched,
    )
}

#[test]
fn overlapped_reproduces_serial_metrics() {
    let Some(dir) = artifacts_dir() else { return };
    let serial = run_mode(dir, PipelineMode::Serial);
    let overlapped = run_mode(dir, PipelineMode::Overlapped);
    assert_eq!(serial.len(), overlapped.len());
    for (s, o) in serial.iter().zip(&overlapped) {
        assert_eq!(
            metric_row(s),
            metric_row(o),
            "training metrics must be schedule-independent at step {}",
            s.step
        );
    }
}

#[test]
fn overlapped_async_at_zero_staleness_reproduces_serial() {
    // With a zero staleness budget the bounded-staleness guard forces
    // the rollout to wait for every update — the serial dataflow on two
    // threads. Training metrics must be bit-identical.
    let Some(dir) = artifacts_dir() else { return };
    let serial = run_mode(dir, PipelineMode::Serial);
    let async0 = run_mode_stale(dir, PipelineMode::OverlappedAsync, 0);
    assert_eq!(serial.len(), async0.len());
    for (s, a) in serial.iter().zip(&async0) {
        assert_eq!(
            metric_row(s),
            metric_row(a),
            "async@staleness=0 diverged from serial at step {}",
            s.step
        );
        assert_eq!(a.param_staleness, 0, "guard must pin staleness to 0");
    }
}

#[test]
fn overlapped_async_staleness_stays_within_budget() {
    // One-step-stale mode: the run completes, every record's staleness
    // respects the budget, and the one-in-flight pipeline can never lag
    // more than a single step anyway.
    let Some(dir) = artifacts_dir() else { return };
    let recs = run_mode_stale(dir, PipelineMode::OverlappedAsync, 1);
    assert_eq!(recs.len(), 5);
    for r in &recs {
        assert!(
            r.param_staleness <= 1,
            "step {} exceeded staleness budget: {}",
            r.step,
            r.param_staleness
        );
        assert!(r.loss.is_finite() && r.entropy.is_finite());
    }
    // Step 1's rollout ran before any update existed: θ_0 is fresh.
    assert_eq!(recs[0].param_staleness, 0);
}

#[test]
fn serial_step_api_matches_serial_run() {
    // `Trainer::step` (the public single-step API) and `run` in Serial
    // mode must walk the same trajectory.
    let Some(dir) = artifacts_dir() else { return };
    let via_run = run_mode(dir, PipelineMode::Serial);
    let cfg = TrainConfig {
        artifacts_dir: dir.to_path_buf(),
        steps: 5,
        seed: 42,
        pipeline: PipelineMode::Serial,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    for want in &via_run {
        let rec = t.step().unwrap();
        assert_eq!(metric_row(&rec), metric_row(want));
    }
}

#[test]
fn forced_replan_switch_preserves_learning_curve() {
    // The re-planner only re-derives the dispatch plan shape — forcing
    // a mid-run parallelism switch must leave the learning curve
    // bit-identical to a run without the re-planner.
    let Some(dir) = artifacts_dir() else { return };
    let baseline = run_mode(dir, PipelineMode::Serial);
    let cfg = TrainConfig {
        artifacts_dir: dir.to_path_buf(),
        steps: 5,
        seed: 42,
        pipeline: PipelineMode::Serial,
        max_staleness: 1,
        replan: true,
        replan_force_step: Some(2),
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    let replanned = t.metrics.records.clone();

    assert_eq!(baseline.len(), replanned.len());
    assert!(
        replanned.iter().any(|r| r.replan_switched),
        "the forced re-plan never switched"
    );
    for (b, r) in baseline.iter().zip(&replanned) {
        assert_eq!(
            metric_row(b),
            metric_row(r),
            "replan switch changed training metrics at step {}",
            b.step
        );
        assert!(!r.replan_config.is_empty(), "decision not recorded");
        assert!(r.ctx_p95 >= 0.0 && r.mem_watermark_frac >= 0.0);
    }
    // The baseline never consulted the planner; its records say so.
    assert!(baseline.iter().all(|r| r.replan_config.is_empty()));
}

/// A 6-phase relay plan: one item's bytes hop 0→1→2→3→0→1→2. The old
/// TCP engine rejected any plan beyond 4 phases.
fn relay_plan_6_phases(bytes: u64) -> DispatchPlan {
    let hops = [(0, 1), (1, 2), (2, 3), (3, 0), (0, 1), (1, 2)];
    DispatchPlan {
        phases: hops
            .iter()
            .map(|&(src, dst)| {
                vec![WorkerTransfer { src, dst, bytes, items: vec![0] }]
            })
            .collect(),
        strategy: "relay-6",
    }
}

#[test]
fn tcp_executes_plan_with_more_than_four_phases() {
    let plan = relay_plan_6_phases(64 << 10);
    let pool = Arc::new(ThreadPool::new(4));
    let rt = TcpRuntime::new(4, None, pool).unwrap();
    let rep = rt.execute(&plan).unwrap();
    assert_eq!(rep.n_phases, 6);
    assert_eq!(rep.phase_seconds.len(), 6);
    assert!(rep.phase_seconds.iter().all(|&s| s >= 0.0));
    assert_eq!(rep.bytes, plan.total_bytes());
    assert_eq!(rep.transfers, 6);

    // Same plan again: every (src, dst) pair is already connected.
    let rep2 = rt.execute(&plan).unwrap();
    assert_eq!(rep2.connections_opened, 0);
    assert_eq!(rep2.bytes, plan.total_bytes());
}

#[test]
fn dispatch_worker_reuses_tcp_connections_across_steps() {
    let p = DataLayout::round_robin(32, 8);
    let c = DataLayout::blocked(32, 8);
    let job = |step: u64| DispatchJob {
        step,
        plan: plan_alltoall(&p, &c, 25_000),
        mode: DispatchMode::Tcp,
        n_workers: 8,
        nic_bytes_per_sec: None,
        payload: None,
        inflight_budget: None,
        adaptive_budget: false,
        reset_budget: false,
        controller_bytes: 0,
        remote: None,
        codec: Codec::None,
    };
    let mut w = DispatchWorker::spawn(Arc::new(ThreadPool::new(8)));
    w.submit(job(0)).unwrap();
    let warm = w.recv().unwrap();
    assert!(warm.connections_opened > 0);
    for step in 1..5 {
        w.submit(job(step)).unwrap();
        let r = w.recv().unwrap();
        assert_eq!(r.step, step);
        assert_eq!(
            r.connections_opened, 0,
            "per-step connect after warmup at step {step}"
        );
    }
}

/// A minimal host-only model state (no PJRT client needed): one 2-elem
/// parameter tensor, step counter set explicitly.
fn tiny_state(step: u64) -> ModelState {
    let lit = |v: f32| Literal::vec1(&[v, -v]);
    ModelState {
        params: vec![lit(step as f32)],
        adam_m: vec![lit(0.0)],
        adam_v: vec![lit(0.0)],
        step,
    }
}

#[test]
fn snapshot_front_step_is_monotone_and_bounded_by_publisher() {
    // Concurrent-publisher invariant of the async pipeline: however the
    // engine thread's reads interleave with the update thread's
    // publishes, `front_step` must be monotone non-decreasing and never
    // exceed the publisher's completed-update counter.
    const STEPS: u64 = 200;
    let buf = Arc::new(SnapshotBuffer::new());
    let completed = Arc::new(AtomicU64::new(0));

    let pub_buf = Arc::clone(&buf);
    let pub_completed = Arc::clone(&completed);
    let publisher = std::thread::spawn(move || {
        for step in 1..=STEPS {
            // The trainer finishes update `step` before publishing θ_step.
            pub_completed.store(step, Ordering::SeqCst);
            pub_buf.publish(&tiny_state(step)).unwrap();
        }
    });

    let deadline = Instant::now() + Duration::from_secs(30);
    let mut last_seen = 0u64;
    loop {
        // Read order matters: front first, then the completed counter —
        // `completed` is bumped before the publish, so any front we
        // observe must be covered by the counter we read afterwards.
        let front = buf.front_step().unwrap_or(0);
        let done = completed.load(Ordering::SeqCst);
        assert!(
            front >= last_seen,
            "front_step regressed: {front} after {last_seen}"
        );
        assert!(
            front <= done,
            "front_step {front} exceeds completed updates {done}"
        );
        last_seen = front;
        if front == STEPS {
            break;
        }
        assert!(Instant::now() < deadline, "publisher stalled at {front}");
        std::thread::yield_now();
    }
    publisher.join().unwrap();
    assert_eq!(buf.front_step(), Some(STEPS));
}

#[test]
fn snapshot_publish_rejects_step_regression() {
    let buf = SnapshotBuffer::new();
    buf.publish(&tiny_state(5)).unwrap();
    assert!(buf.publish(&tiny_state(3)).is_err(), "regression accepted");
    assert_eq!(buf.front_step(), Some(5));
    // Equal and newer steps are fine (re-publish after a no-op).
    buf.publish(&tiny_state(5)).unwrap();
    buf.publish(&tiny_state(6)).unwrap();
    assert_eq!(buf.front_step(), Some(6));
}

#[test]
fn snapshot_acquire_enforces_staleness_bound() {
    let buf = Arc::new(SnapshotBuffer::new());
    // Nothing published: acquire must time out, not hang.
    assert!(buf.acquire(0, Duration::from_millis(50)).is_err());

    buf.publish(&tiny_state(4)).unwrap();
    // Within budget: returns immediately with the front snapshot.
    let snap = buf.acquire(4, Duration::from_millis(50)).unwrap();
    assert_eq!(snap.step, 4);
    // Too stale for the requested bound: refused (by timeout).
    assert!(buf.acquire(5, Duration::from_millis(50)).is_err());

    // A publisher catching up unblocks a waiting acquire.
    let pub_buf = Arc::clone(&buf);
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        pub_buf.publish(&tiny_state(5)).unwrap();
    });
    let fresh = buf.acquire(5, Duration::from_secs(10)).unwrap();
    assert_eq!(fresh.step, 5);
    h.join().unwrap();

    // An old Arc handed out earlier stays readable after later
    // publishes (the reader's copy is never torn out from under it).
    assert_eq!(snap.step, 4);
    assert_eq!(snap.params.len(), 1);
}

#[test]
fn pipelined_submit_then_recv_preserves_order_across_modes() {
    // Mixed simulated/real jobs through the same worker: results come
    // back in submission order with the right step ids.
    let p = DataLayout::round_robin(16, 4);
    let c = DataLayout::blocked(16, 4);
    let mk = |step: u64, mode: DispatchMode| DispatchJob {
        step,
        plan: plan_alltoall(&p, &c, 10_000),
        mode,
        n_workers: 4,
        nic_bytes_per_sec: None,
        payload: None,
        inflight_budget: None,
        adaptive_budget: false,
        reset_budget: false,
        controller_bytes: 0,
        remote: None,
        codec: Codec::None,
    };
    let mut w = DispatchWorker::spawn(Arc::new(ThreadPool::new(4)));
    w.submit(mk(1, DispatchMode::Simulated)).unwrap();
    w.submit(mk(2, DispatchMode::Tcp)).unwrap();
    let a = w.recv().unwrap();
    w.submit(mk(3, DispatchMode::SimulatedCentralized)).unwrap();
    let b = w.recv().unwrap();
    let c2 = w.recv().unwrap();
    assert_eq!((a.step, b.step, c2.step), (1, 2, 3));
    assert!(a.modeled_seconds > 0.0);
    assert!(b.wall_seconds > 0.0);
    assert!(c2.modeled_seconds > 0.0);
}
