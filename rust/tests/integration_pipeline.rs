//! Integration: the pipelined step engine — Overlapped mode must
//! reproduce Serial-mode training metrics for a fixed seed (the overlap
//! is a pure systems change), and the persistent TCP dispatch runtime
//! must execute arbitrary-phase plans while reusing connections across
//! steps.

use std::path::Path;
use std::sync::Arc;

use earl::config::TrainConfig;
use earl::coordinator::{
    DispatchJob, DispatchMode, DispatchWorker, PipelineMode, Trainer,
};
use earl::dispatch::{
    plan_alltoall, DataLayout, DispatchPlan, TcpRuntime, WorkerTransfer,
};
use earl::metrics::StepRecord;
use earl::util::threadpool::ThreadPool;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Box::leak(dir.into_boxed_path()))
    } else {
        eprintln!("artifacts/ missing — run `make artifacts`; skipping");
        None
    }
}

fn run_mode(dir: &Path, mode: PipelineMode) -> Vec<StepRecord> {
    let cfg = TrainConfig {
        artifacts_dir: dir.to_path_buf(),
        steps: 5,
        seed: 42,
        pipeline: mode,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    t.metrics.records.clone()
}

/// Training metrics (not timings) of a record, for cross-mode equality.
fn metric_row(r: &StepRecord) -> (u64, f64, f64, f64, f64, f64, f64, usize, bool) {
    (
        r.step,
        r.mean_return,
        r.mean_episode_ctx,
        r.mean_turn_ctx,
        r.loss,
        r.kl,
        r.entropy,
        r.bucket,
        r.selector_switched,
    )
}

#[test]
fn overlapped_reproduces_serial_metrics() {
    let Some(dir) = artifacts_dir() else { return };
    let serial = run_mode(dir, PipelineMode::Serial);
    let overlapped = run_mode(dir, PipelineMode::Overlapped);
    assert_eq!(serial.len(), overlapped.len());
    for (s, o) in serial.iter().zip(&overlapped) {
        assert_eq!(
            metric_row(s),
            metric_row(o),
            "training metrics must be schedule-independent at step {}",
            s.step
        );
    }
}

#[test]
fn serial_step_api_matches_serial_run() {
    // `Trainer::step` (the public single-step API) and `run` in Serial
    // mode must walk the same trajectory.
    let Some(dir) = artifacts_dir() else { return };
    let via_run = run_mode(dir, PipelineMode::Serial);
    let cfg = TrainConfig {
        artifacts_dir: dir.to_path_buf(),
        steps: 5,
        seed: 42,
        pipeline: PipelineMode::Serial,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    for want in &via_run {
        let rec = t.step().unwrap();
        assert_eq!(metric_row(&rec), metric_row(want));
    }
}

/// A 6-phase relay plan: one item's bytes hop 0→1→2→3→0→1→2. The old
/// TCP engine rejected any plan beyond 4 phases.
fn relay_plan_6_phases(bytes: u64) -> DispatchPlan {
    let hops = [(0, 1), (1, 2), (2, 3), (3, 0), (0, 1), (1, 2)];
    DispatchPlan {
        phases: hops
            .iter()
            .map(|&(src, dst)| {
                vec![WorkerTransfer { src, dst, bytes, items: vec![0] }]
            })
            .collect(),
        strategy: "relay-6",
    }
}

#[test]
fn tcp_executes_plan_with_more_than_four_phases() {
    let plan = relay_plan_6_phases(64 << 10);
    let pool = Arc::new(ThreadPool::new(4));
    let rt = TcpRuntime::new(4, None, pool).unwrap();
    let rep = rt.execute(&plan).unwrap();
    assert_eq!(rep.n_phases, 6);
    assert_eq!(rep.phase_seconds.len(), 6);
    assert!(rep.phase_seconds.iter().all(|&s| s >= 0.0));
    assert_eq!(rep.bytes, plan.total_bytes());
    assert_eq!(rep.transfers, 6);

    // Same plan again: every (src, dst) pair is already connected.
    let rep2 = rt.execute(&plan).unwrap();
    assert_eq!(rep2.connections_opened, 0);
    assert_eq!(rep2.bytes, plan.total_bytes());
}

#[test]
fn dispatch_worker_reuses_tcp_connections_across_steps() {
    let p = DataLayout::round_robin(32, 8);
    let c = DataLayout::blocked(32, 8);
    let job = |step: u64| DispatchJob {
        step,
        plan: plan_alltoall(&p, &c, 25_000),
        mode: DispatchMode::Tcp,
        n_workers: 8,
        nic_bytes_per_sec: None,
    };
    let mut w = DispatchWorker::spawn(Arc::new(ThreadPool::new(8)));
    w.submit(job(0)).unwrap();
    let warm = w.recv().unwrap();
    assert!(warm.connections_opened > 0);
    for step in 1..5 {
        w.submit(job(step)).unwrap();
        let r = w.recv().unwrap();
        assert_eq!(r.step, step);
        assert_eq!(
            r.connections_opened, 0,
            "per-step connect after warmup at step {step}"
        );
    }
}

#[test]
fn pipelined_submit_then_recv_preserves_order_across_modes() {
    // Mixed simulated/real jobs through the same worker: results come
    // back in submission order with the right step ids.
    let p = DataLayout::round_robin(16, 4);
    let c = DataLayout::blocked(16, 4);
    let mk = |step: u64, mode: DispatchMode| DispatchJob {
        step,
        plan: plan_alltoall(&p, &c, 10_000),
        mode,
        n_workers: 4,
        nic_bytes_per_sec: None,
    };
    let mut w = DispatchWorker::spawn(Arc::new(ThreadPool::new(4)));
    w.submit(mk(1, DispatchMode::Simulated)).unwrap();
    w.submit(mk(2, DispatchMode::Tcp)).unwrap();
    let a = w.recv().unwrap();
    w.submit(mk(3, DispatchMode::SimulatedCentralized)).unwrap();
    let b = w.recv().unwrap();
    let c2 = w.recv().unwrap();
    assert_eq!((a.step, b.step, c2.step), (1, 2, 3));
    assert!(a.modeled_seconds > 0.0);
    assert!(b.wall_seconds > 0.0);
    assert!(c2.modeled_seconds > 0.0);
}
