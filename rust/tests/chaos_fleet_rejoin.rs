//! Chaos: rollout-worker death and mid-run rejoin against a 2-process
//! fleet.
//!
//! The elastic-fleet contract: a killed rollout worker's episode slice
//! re-plans onto a survivor (or falls back to bit-identical local
//! generation), a restarted process **rejoins mid-run** under its old
//! id with a bumped generation — the gap the ingest fleet leaves open —
//! and none of it can disturb the learning curve, because episode
//! content is a pure function of `(θ, seed, step, global index)`. Even
//! losing the whole fleet only degrades to local generation; the run
//! never stalls and never diverges.
//!
//! Runs without the `xla` feature (CI job `core-no-xla`,
//! `make check-core`).

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use earl::coordinator::{FleetCfg, FleetCoordinator};

/// A spawned `earl worker --rollout` process, killed on drop even if
/// the test panics first.
struct WorkerProc {
    child: Child,
    addr: SocketAddr,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl WorkerProc {
    fn kill(&mut self) {
        self.child.kill().unwrap();
        self.child.wait().unwrap();
    }
}

fn spawn_rollout_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_earl"))
        .args(["worker", "--listen", "127.0.0.1:0", "--rollout", "--quiet"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning earl worker --rollout");
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .and_then(|a| a.parse().ok())
        .unwrap_or_else(|| panic!("unparseable worker banner {line:?}"));
    WorkerProc { child, addr }
}

#[test]
fn kill_and_rejoin_keep_the_curve_bit_identical() {
    const STEPS: usize = 8;
    let cfg = FleetCfg {
        seed: 23,
        max_staleness: 0,
        io_timeout: Duration::from_secs(10),
        ..FleetCfg::default()
    };

    // Serial reference for the whole trajectory.
    let mut serial = FleetCoordinator::local(cfg.clone()).unwrap();
    let mut reference = Vec::new();
    for _ in 0..STEPS {
        reference.push(serial.step().unwrap());
    }

    let mut workers: Vec<WorkerProc> =
        (0..2).map(|_| spawn_rollout_worker()).collect();
    let mut coord = FleetCoordinator::fleet(cfg.clone()).unwrap();
    for w in &workers {
        coord.join(w.addr).unwrap();
    }
    assert_eq!(coord.live_workers(), vec![0, 1]);

    let t0 = Instant::now();
    for (k, want) in reference.iter().enumerate() {
        // Chaos schedule: worker 1 dies before step 2, a restarted
        // process rejoins under its id before step 4, and the whole
        // fleet dies before step 6 — the final steps run all-local.
        if k == 2 {
            workers[1].kill();
        }
        if k == 4 {
            workers[1] = spawn_rollout_worker();
            let generation = coord.rejoin(1, workers[1].addr).unwrap();
            assert_eq!(
                generation, 1,
                "rejoin must bump the manifest generation"
            );
            assert_eq!(coord.live_workers(), vec![0, 1]);
        }
        if k == 6 {
            workers[0].kill();
            workers[1].kill();
        }
        let got = coord.step().unwrap_or_else(|e| {
            panic!("chaos step {k} failed to recover: {e:#}")
        });
        assert_eq!(
            got.training_row(),
            want.training_row(),
            "chaos step {k} diverged from the serial reference"
        );
        assert_eq!(
            got.episodes_from_fleet + got.episodes_local,
            cfg.episodes as u64,
            "step {k} lost episodes"
        );
        match k {
            // Both workers live: the whole range is fleet-served.
            0 | 1 | 4 | 5 => {
                assert_eq!(got.episodes_from_fleet, cfg.episodes as u64);
                assert_eq!(got.redispatches, 0, "step {k} re-dispatched");
            }
            // Worker 1 just died: the loss surfaces at the snapshot
            // push, and the survivor carries the whole range.
            2 | 3 => {
                assert_eq!(coord.live_workers(), vec![0]);
                assert_eq!(
                    got.episodes_from_fleet + got.episodes_local,
                    cfg.episodes as u64
                );
            }
            // Whole fleet dead: pure local fallback.
            6 | 7 => {
                assert_eq!(got.episodes_local, cfg.episodes as u64);
                assert_eq!(got.episodes_from_fleet, 0);
            }
            _ => {}
        }
        assert_eq!(
            got.max_snapshot_staleness, 0,
            "staleness floor 0 must pin every episode to this step's θ"
        );
    }
    assert!(
        t0.elapsed() < Duration::from_secs(240),
        "chaos recovery must not hang"
    );
    // Same parameters, bit for bit, through death, rejoin, and total
    // fleet loss.
    assert_eq!(coord.model, serial.model);
    assert_eq!(coord.model.step, STEPS as u64);
    // The membership history survives it all: worker 1's entry carries
    // its rejoin generation.
    assert_eq!(coord.client.manifest.get(1).unwrap().generation, 1);
    assert_eq!(coord.client.manifest.len(), 2);
}
