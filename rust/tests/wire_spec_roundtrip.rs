//! Exhaustive encode/decode roundtrips generated from the analyzer's
//! *extracted* wire spec — not from a hand-maintained table. The
//! `earl-analyze` wirespec pass parses `dispatch/wire.rs` into a
//! machine-readable protocol spec (enum code tables, fixed layouts,
//! checksum stream); this test turns that spec back on the live types,
//! so a code-table edit that dodges the static checks still has to
//! survive an exhaustive roundtrip here.

use earl::analyze::source::parse_source;
use earl::analyze::wirespec;
use earl::analyze::WIRE_MODULE;
use earl::dispatch::wire::{
    Codec, FrameHeader, ShardDesc, WireDtype, WireTensorId, FRAME_HEADER_LEN,
    SHARD_DESC_LEN, WIRE_MAGIC,
};

fn wire_spec() -> wirespec::WireSpec {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/src/dispatch/wire.rs");
    let src = std::fs::read_to_string(path).expect("read wire.rs");
    let file = parse_source(WIRE_MODULE, &src);
    let (spec, findings) = wirespec::analyze(&file);
    // The committed wire module must be self-consistent before the
    // spec is trusted to generate cases.
    assert!(
        findings.is_empty(),
        "wirespec findings on the committed wire.rs: {:?}",
        findings.iter().map(|f| f.render()).collect::<Vec<_>>()
    );
    spec
}

#[test]
fn extracted_tensor_id_table_matches_the_live_enum() {
    let spec = wire_spec();
    let e = spec.enums.get("WireTensorId").expect("WireTensorId spec");

    // Every live variant appears in the extracted code table with the
    // live code, and nothing else does.
    assert_eq!(e.codes.len(), WireTensorId::ALL.len());
    assert_eq!(e.all_len, Some(WireTensorId::ALL.len() as u64));
    for id in WireTensorId::ALL {
        let name = format!("{id:?}");
        let code = e
            .codes
            .iter()
            .find(|(v, _)| *v == name)
            .unwrap_or_else(|| panic!("{name} missing from extracted spec"));
        assert_eq!(code.1, id.code() as u64, "{name} code drifted");
    }
    // The `ALL` iteration table covers every variant (spec-side check
    // of what the exhaustive scans below verify value-side).
    let all = e.all.as_ref().expect("ALL table extracted");
    for (v, _) in &e.codes {
        assert!(all.contains(v), "{v} missing from ALL");
    }
}

#[test]
fn tensor_id_from_code_is_exhaustive_over_u16() {
    let spec = wire_spec();
    let e = spec.enums.get("WireTensorId").expect("WireTensorId spec");
    let valid: std::collections::BTreeSet<u64> =
        e.codes.iter().map(|(_, c)| *c).collect();

    for c in 0..=u16::MAX {
        match WireTensorId::from_code(c) {
            Ok(id) => {
                assert!(
                    valid.contains(&(c as u64)),
                    "from_code accepted {c:#x}, absent from the spec"
                );
                assert_eq!(id.code(), c, "code/from_code not inverse at {c:#x}");
            }
            Err(_) => assert!(
                !valid.contains(&(c as u64)),
                "from_code rejected spec'd code {c:#x}"
            ),
        }
    }
}

#[test]
fn dtype_from_code_is_exhaustive_over_u8() {
    let spec = wire_spec();
    let e = spec.enums.get("WireDtype").expect("WireDtype spec");
    let valid: std::collections::BTreeSet<u64> =
        e.codes.iter().map(|(_, c)| *c).collect();

    for c in 0..=u8::MAX {
        match WireDtype::from_code(c) {
            Ok(d) => {
                assert!(valid.contains(&(c as u64)));
                assert_eq!(d.code(), c);
            }
            Err(_) => assert!(!valid.contains(&(c as u64))),
        }
    }
}

#[test]
fn shard_desc_roundtrips_for_every_variant_and_dtype() {
    let spec = wire_spec();
    let layout = spec.layouts.get("ShardDesc").expect("ShardDesc layout");
    assert_eq!(layout.len as usize, SHARD_DESC_LEN);

    for tensor in WireTensorId::ALL {
        for dtype in [WireDtype::I32, WireDtype::F32] {
            for codec in Codec::ALL {
                let desc = ShardDesc {
                    tensor,
                    dtype,
                    codec,
                    row_start: 0x0102_0304,
                    rows: 0x0A0B_0C0D,
                    row_bytes: 0xF00D_BEEF,
                    wire_bytes: 0x0011_2233_4455_6677,
                };
                let bytes = desc.encode();
                assert_eq!(bytes.len(), layout.len as usize);
                let back = ShardDesc::decode(&bytes).unwrap_or_else(|e| {
                    panic!("decode {tensor:?}/{dtype:?}/{codec:?}: {e}")
                });
                assert_eq!(
                    back, desc,
                    "roundtrip drift for {tensor:?}/{dtype:?}/{codec:?}"
                );
                // Declared padding holes stay zero on the wire (they
                // are covered by the checksum, so garbage there would
                // make equal frames compare unequal).
                for &hole in &layout.holes {
                    assert_eq!(
                        bytes[hole as usize], 0,
                        "pad byte {hole} of ShardDesc not zeroed"
                    );
                }
            }
        }
    }
}

#[test]
fn codec_from_code_is_exhaustive_over_u8() {
    let spec = wire_spec();
    let e = spec.enums.get("Codec").expect("Codec spec");
    let valid: std::collections::BTreeSet<u64> =
        e.codes.iter().map(|(_, c)| *c).collect();
    assert_eq!(e.codes.len(), Codec::ALL.len());

    for c in 0..=u8::MAX {
        match Codec::from_code(c) {
            Ok(k) => {
                assert!(valid.contains(&(c as u64)));
                assert_eq!(k.code(), c);
            }
            Err(_) => assert!(!valid.contains(&(c as u64))),
        }
    }
}

#[test]
fn frame_header_roundtrips_at_the_spec_width() {
    let spec = wire_spec();
    let layout = spec.layouts.get("FrameHeader").expect("FrameHeader layout");
    assert_eq!(layout.len as usize, FRAME_HEADER_LEN);
    assert_eq!(spec.consts.get("FRAME_HEADER_LEN"), Some(&40));
    assert_eq!(spec.consts.get("SHARD_DESC_LEN"), Some(&24));
    assert_eq!(spec.consts.get("WIRE_MAGIC"), Some(&(WIRE_MAGIC as u64)));
    assert!(layout.holes.is_empty(), "FrameHeader grew padding");

    let h = FrameHeader {
        src: u64::MAX - 3,
        epoch: 0x1122_3344_5566_7788,
        bytes: 7,
        n_shards: 0xDEAD_0001,
        checksum: 0xCAFE_F00D_1234_5678,
    };
    let bytes = h.encode();
    assert_eq!(bytes.len(), layout.len as usize);
    let back = FrameHeader::decode(&bytes).expect("decode");
    assert_eq!(back, h);

    // Corrupting the magic must fail decode, not mis-frame.
    let mut bad = bytes;
    bad[0] ^= 0xFF;
    assert!(FrameHeader::decode(&bad).is_err(), "bad magic accepted");
}
