//! Integration: **remote worker ingestion** — spawned `earl worker
//! --ingest` processes consume dispatched shards into real update
//! steps, and the coordinator merges their results into the live model.
//!
//! * A 2-process run must reproduce the local serial reference
//!   **step for step** (same equality pattern as the
//!   `integration_pipeline.rs` determinism tests: the deployment is a
//!   systems change, not a training change).
//! * Aggregation-aware planning (paper §3.3) must measurably shrink
//!   `dispatch_bytes`: the whitened advantages route through the
//!   controller's commit frames, not the peer-to-peer wire.
//! * Failure injection: killing a worker mid-run re-plans its rows onto
//!   the survivor and the run continues bit-identically; killing *all*
//!   workers surfaces a deterministic error — no hang, no partial merge
//!   (the model is untouched). `tests/chaos_worker_death.rs` extends
//!   this to 3-worker kill/restart schedules.
//!
//! Runs without the `xla` feature (CI job `core-no-xla`,
//! `make check-core`): ingestion is PJRT-free by construction.

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use earl::coordinator::{IngestCfg, IngestCoordinator};

/// A spawned `earl worker --ingest` process, killed on drop even if the
/// test panics first.
struct WorkerProc {
    child: Child,
    addr: SocketAddr,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_ingest_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_earl"))
        .args(["worker", "--listen", "127.0.0.1:0", "--ingest", "--quiet"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning earl worker --ingest");
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .and_then(|a| a.parse().ok())
        .unwrap_or_else(|| panic!("unparseable worker banner {line:?}"));
    WorkerProc { child, addr }
}

fn cfg() -> IngestCfg {
    IngestCfg {
        n_workers: 2,
        rows: 8,
        seq: 24,
        vocab: 16,
        seed: 7,
        commit_timeout: Duration::from_secs(60),
        ..IngestCfg::default()
    }
}

#[test]
fn two_process_run_reproduces_local_serial_learning_curve() {
    const STEPS: usize = 4;
    let cfg = cfg();
    let full_bytes = (cfg.rows * cfg.seq * 4 * 4) as u64; // 4 tensors
    let wire_bytes = (cfg.rows * cfg.seq * 4 * 3) as u64; // − advantages

    // Local serial reference: per-worker partials computed in-process,
    // identical math, no sockets.
    let mut serial = IngestCoordinator::local(cfg.clone()).unwrap();
    let mut reference = Vec::new();
    for _ in 0..STEPS {
        reference.push(serial.step().unwrap());
    }

    // The same trajectory through two real worker processes.
    let workers: Vec<WorkerProc> =
        (0..2).map(|_| spawn_ingest_worker()).collect();
    let addrs: Vec<SocketAddr> = workers.iter().map(|w| w.addr).collect();
    let mut remote =
        IngestCoordinator::connect(cfg.clone(), addrs.clone()).unwrap();
    assert!(remote.is_remote());
    for (k, want) in reference.iter().enumerate() {
        let got = remote.step().unwrap();
        assert_eq!(
            got.training_row(),
            want.training_row(),
            "multi-process run diverged from serial at step {k}"
        );
        // Aggregation-aware planning ships only the wire tensors.
        assert_eq!(got.dispatch_bytes, wire_bytes);
        assert_eq!(got.controller_bytes, full_bytes - wire_bytes);
        assert!(
            got.dispatch_bytes < full_bytes,
            "aggregation-aware plan failed to shrink the wire"
        );
    }
    // The models agree exactly — same parameters, bit for bit.
    assert_eq!(remote.model, serial.model);
    assert_eq!(remote.model.step, STEPS as u64);
    // Worker-reported metrics merged (summed) across both workers.
    for (step, m) in remote.metrics.worker_steps.iter() {
        assert_eq!(m.rows, cfg.rows as u64, "step {step} lost worker rows");
        assert_eq!(m.row_tokens.total(), cfg.rows as u64);
    }
    drop(remote); // close sender connections before the next run

    // Aggregation-UNAWARE comparison run against the same workers: the
    // whole payload (advantages included) rides the wire — measurably
    // more dispatched bytes for the same learning step.
    let mut unaware = IngestCoordinator::connect(
        IngestCfg { aggregation_aware: false, ..cfg },
        addrs,
    )
    .unwrap();
    let r = unaware.step().unwrap();
    assert_eq!(r.dispatch_bytes, full_bytes);
    assert_eq!(r.controller_bytes, 0);
    assert!(
        r.dispatch_bytes > wire_bytes,
        "aggregation-aware planning must reduce dispatch_bytes \
         ({wire_bytes} aware vs {} unaware)",
        r.dispatch_bytes
    );
    // Same training outcome either way: routing is a systems choice.
    assert_eq!(r.training_row(), reference[0].training_row());
}

#[test]
fn killed_worker_recovers_by_redispatch_and_total_loss_is_an_error() {
    const STEPS: usize = 4;
    let cfg = IngestCfg {
        commit_timeout: Duration::from_secs(30),
        ..cfg()
    };
    // Serial reference for the whole trajectory, deaths and all: the
    // re-plan is a systems change, not a training change.
    let mut serial = IngestCoordinator::local(cfg.clone()).unwrap();
    let mut reference = Vec::new();
    for _ in 0..STEPS {
        reference.push(serial.step().unwrap());
    }

    let mut workers: Vec<WorkerProc> =
        (0..2).map(|_| spawn_ingest_worker()).collect();
    let addrs: Vec<SocketAddr> = workers.iter().map(|w| w.addr).collect();
    let mut coord = IngestCoordinator::connect(cfg, addrs).unwrap();

    // Healthy warmup: two steps complete cleanly.
    for want in &reference[..2] {
        let got = coord.step().unwrap();
        assert_eq!(got.training_row(), want.training_row());
        assert_eq!(got.redispatches, 0);
    }

    // Kill one worker: the next step must *complete* by re-planning the
    // dead worker's rows onto the survivor, bit-identical to serial.
    {
        let victim = &mut workers[1];
        victim.child.kill().unwrap();
        victim.child.wait().unwrap();
    }
    let t0 = Instant::now();
    for (k, want) in reference.iter().enumerate().skip(2) {
        let got = coord.step().unwrap_or_else(|e| {
            panic!("step {k} failed to recover from a dead worker: {e:#}")
        });
        assert_eq!(
            got.training_row(),
            want.training_row(),
            "re-dispatched step {k} diverged from serial"
        );
        assert!(
            got.redispatches >= 1,
            "step {k} recovered without recording its re-dispatch"
        );
    }
    assert!(
        t0.elapsed() < Duration::from_secs(120),
        "recovery must not hang"
    );
    assert_eq!(coord.model, serial.model);
    // Merged worker metrics still account for every row per step.
    for (step, m) in coord.metrics.worker_steps.iter() {
        assert_eq!(m.rows, 8, "step {step} lost worker rows");
    }

    // Kill the survivor too: with *all* workers gone the step fails
    // deterministically and the model is untouched.
    let step_before = coord.model.step;
    let params_before = coord.model.w.clone();
    {
        let victim = &mut workers[0];
        victim.child.kill().unwrap();
        victim.child.wait().unwrap();
    }
    let t1 = Instant::now();
    let err = coord.step();
    assert!(err.is_err(), "step with every worker dead must fail");
    assert!(
        t1.elapsed() < Duration::from_secs(60),
        "total-loss failure must surface promptly, not hang"
    );
    assert_eq!(coord.model.step, step_before);
    assert_eq!(coord.model.w, params_before);
    // Sticky-deterministic: retrying keeps failing cleanly.
    assert!(coord.step().is_err());
    assert_eq!(coord.model.w, params_before);
    // The metrics log never saw a worker report for the failed step.
    assert!(!coord.metrics.worker_steps.contains_key(&(step_before + 1)));
}
