//! **End-to-end validation driver** — trains the AOT transformer with
//! REINFORCE self-play on Tic-Tac-Toe for a few hundred steps through
//! the complete stack (Pallas attention kernel → JAX model → HLO → PJRT
//! → rollout → exp-prep → dispatch → fused train step), logging the
//! return/loss curves to runs/e2e_metrics.jsonl. Recorded run:
//! EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example train_e2e -- [steps] [env]

use anyhow::Result;

use earl::config::{EnvKind, TrainConfig};
use earl::coordinator::Trainer;

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let env = std::env::args()
        .nth(2)
        .map(|s| EnvKind::from_name(&s))
        .transpose()?
        .unwrap_or(EnvKind::TicTacToe);

    let mut cfg = TrainConfig::default();
    cfg.artifacts_dir =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.steps = steps;
    cfg.env = env;
    cfg.seed = 42;
    cfg.hp.lr = 1e-3;
    cfg.hp.ent_coef = 0.02;
    cfg.hp.kl_coef = 0.02;
    cfg.ref_refresh_every = 50;
    cfg.rollout.max_response_tokens = 4;
    std::fs::create_dir_all("runs").ok();
    cfg.metrics_path = Some("runs/e2e_metrics.jsonl".into());
    cfg.checkpoint_path = Some("runs/e2e_final_params.bin".into());

    println!(
        "=== end-to-end: {} steps of agentic RL on {} (model {} params) ===",
        steps,
        env.name(),
        "see manifest"
    );
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "model: {} params | buckets {:?} | batch {}",
        trainer.engine.manifest.model.n_params,
        trainer.engine.manifest.buckets,
        trainer.engine.manifest.batch
    );

    let mut first20 = 0.0;
    for i in 0..steps {
        let rec = trainer.step()?;
        if i == 19 {
            first20 = trainer.metrics.rolling_return(20);
        }
        if rec.step % 10 == 0 || rec.step == steps {
            println!(
                "step {:>4} | return {:+.3} (roll20 {:+.3}) | ep-ctx {:>5.1} | \
                 loss {:+.4} | kl {:.4} | ent {:.3} | bucket {} | \
                 step-time {:.2}s",
                rec.step,
                rec.mean_return,
                trainer.metrics.rolling_return(20),
                rec.mean_episode_ctx,
                rec.loss,
                rec.kl,
                rec.entropy,
                rec.bucket,
                rec.step_seconds(),
            );
        }
    }
    let final20 = trainer.metrics.rolling_return(20);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n=== e2e done: {steps} steps in {:.0}s ({:.2}s/step) ===",
        wall,
        wall / steps as f64
    );
    println!(
        "rolling return: first-20 {first20:+.3} -> last-20 {final20:+.3} \
         (improvement {:+.3})",
        final20 - first20
    );
    println!("metrics: runs/e2e_metrics.jsonl; checkpoint: runs/e2e_final_params.bin");
    Ok(())
}
