#!/usr/bin/env bash
# Multi-process data dispatcher demo: spawn two `earl worker` receive-side
# processes, then drive the Fig. 4 dispatch benchmark against them over
# real sockets — checksummed frames carrying real bytes, per-frame acks,
# and a per-NIC in-flight budget. A second leg spawns two `earl worker
# --ingest` processes and runs distributed update steps through them
# (remote ingestion, paper 3.3): the workers consume the dispatched
# shards into worker-local updates and the coordinator merges their
# results — printing the same learning curve a serial run produces.
#
# Works with the XLA-free core build too:
#   cd rust && cargo build --release --no-default-features
#
# Usage: examples/multi_process_dispatch.sh [budget_bytes]

set -euo pipefail
cd "$(dirname "$0")/.."

BUDGET="${1:-1048576}"   # 1 MiB per-NIC in-flight budget by default
EARL=rust/target/release/earl

if [ ! -x "$EARL" ]; then
    echo "building earl (release)..."
    (cd rust && cargo build --release)
fi

cleanup() {
    [ -n "${W1_PID:-}" ] && kill "$W1_PID" 2>/dev/null || true
    [ -n "${W2_PID:-}" ] && kill "$W2_PID" 2>/dev/null || true
    [ -n "${I1_PID:-}" ] && kill "$I1_PID" 2>/dev/null || true
    [ -n "${I2_PID:-}" ] && kill "$I2_PID" 2>/dev/null || true
}
trap cleanup EXIT

# Each worker binds an ephemeral port and prints it on stdout.
mkfifo_out1=$(mktemp)
mkfifo_out2=$(mktemp)
"$EARL" worker --listen 127.0.0.1:0 --quiet >"$mkfifo_out1" &
W1_PID=$!
"$EARL" worker --listen 127.0.0.1:0 --quiet >"$mkfifo_out2" &
W2_PID=$!

addr_of() {
    local f=$1
    for _ in $(seq 1 50); do
        if grep -q "listening on" "$f" 2>/dev/null; then
            awk '{print $NF}' "$f"
            return 0
        fi
        sleep 0.1
    done
    echo "worker failed to report an address" >&2
    exit 1
}

A1=$(addr_of "$mkfifo_out1")
A2=$(addr_of "$mkfifo_out2")
echo "workers: $A1 $A2 (budget ${BUDGET}B per NIC)"

"$EARL" dispatch-bench --connect "$A1,$A2" --scale 0.02 --budget "$BUDGET"

rm -f "$mkfifo_out1" "$mkfifo_out2"
echo "done — every frame above was checksummed and acked by the workers."

# ---------------------------------------------------------------------------
# Remote ingestion: workers that *consume* what the dispatcher ships.
# ---------------------------------------------------------------------------
echo
echo "== remote ingestion demo: 2 x 'earl worker --ingest' =="

ingest_out1=$(mktemp)
ingest_out2=$(mktemp)
"$EARL" worker --listen 127.0.0.1:0 --ingest --quiet >"$ingest_out1" &
I1_PID=$!
"$EARL" worker --listen 127.0.0.1:0 --ingest --quiet >"$ingest_out2" &
I2_PID=$!
B1=$(addr_of "$ingest_out1")
B2=$(addr_of "$ingest_out2")
echo "ingest workers: $B1 $B2"

# The serial reference, then the same seed through the two processes —
# the training rows (loss, grad_norm) and final params line must match.
"$EARL" ingest-demo --steps 5 --seed 42 --workers 2
"$EARL" ingest-demo --steps 5 --seed 42 --connect "$B1,$B2" --budget "$BUDGET"

rm -f "$ingest_out1" "$ingest_out2"
echo "done — the workers ran the update steps; the coordinator only merged."
