//! **Fig. 3 walkthrough** — the Parallelism Selector end to end on the
//! simulated paper testbed: profile the TP4/TP8 grid, build the
//! context-range table, then replay a growing-context training run and
//! watch the switch happen (the paper's §3.2 narrative).
//!
//!     cargo run --release --example parallelism_sweep

use earl::cluster::ClusterSpec;
use earl::parallelism::{
    decode_estimate, ModelShape, ParallelismConfig, ProfilePoint, RangeTable,
    Selector, ThroughputCfg,
};
use earl::workload::ContextTrace;

fn main() {
    let shape = ModelShape::qwen2_5_72b();
    let cluster = ClusterSpec::paper_testbed();
    let tcfg = ThroughputCfg::default();
    let responses = 32;

    // --- offline profiling pass (paper §2: "at the start of the training
    // process, EARL measures the throughput under various parallelism
    // configurations and context lengths") ---
    println!("== profiling: decode TGS (tokens/GPU/s), Qwen2.5-72B, resp={responses} ==");
    println!("{:>8} {:>10} {:>10} {:>10}", "ctx", "TP2", "TP4", "TP8");
    let ctx_grid = [2048usize, 4096, 8192, 16384, 32768];
    let mut points = Vec::new();
    for &ctx in &ctx_grid {
        print!("{ctx:>8}");
        for tp in [2usize, 4, 8] {
            let e = decode_estimate(
                &shape,
                &cluster,
                ParallelismConfig::tp(tp),
                &tcfg,
                ctx,
                responses,
            );
            match &e {
                Some(e) => print!("{:>10.0}", e.tgs),
                None => print!("{:>10}", "OOM"),
            }
            points.push(ProfilePoint {
                config: tp,
                ctx,
                tgs: e.map(|e| e.tgs),
            });
        }
        println!();
    }

    // --- the range table the selector keeps ---
    let table = RangeTable::from_profile(&points).expect("feasible");
    println!("\n== selected configuration per context range ==");
    for (bound, tp, tgs) in table.entries() {
        println!("  ctx <= {bound:>6}: TP{tp} ({tgs:.0} TGS)");
    }

    // --- online: replay a growing-context run ---
    println!("\n== online replay: context grows across training steps ==");
    let mut selector = Selector::new(table, 0.35, 2048);
    let trace = ContextTrace::logistic(30, 2048.0, 36000.0, 0.3, 0.04, 3);
    for (step, &ctx) in trace.steps.iter().enumerate() {
        selector.observe(ctx);
        let d = selector.decide();
        if d.switched() || step % 5 == 0 {
            println!(
                "  step {step:>2}: observed ctx {ctx:>7.0}  ema {:>7.0}  -> TP{}{}",
                selector.observed_ctx().unwrap_or(0.0),
                d.config(),
                if d.switched() { "   [SWITCH before next rollout]" } else { "" }
            );
        }
    }
    println!(
        "\ntotal switches: {} (paper: TP4 at short ctx, TP8 from 16K on)",
        selector.switches
    );
}
