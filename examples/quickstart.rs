//! Quickstart: load the AOT artifacts, run a handful of end-to-end RL
//! steps on Tic-Tac-Toe, and print what each EARL stage did.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use earl::config::TrainConfig;
use earl::coordinator::Trainer;

fn main() -> Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.steps = 5;
    cfg.seed = 7;
    // Artifacts relative to the workspace root.
    cfg.artifacts_dir =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    println!("EARL quickstart: {} steps of agentic RL on TicTacToe\n", cfg.steps);
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "model: {} params, buckets {:?}, batch {}",
        trainer.engine.manifest.model.n_params,
        trainer.engine.manifest.buckets,
        trainer.engine.manifest.batch,
    );

    for _ in 0..trainer.cfg.steps {
        let rec = trainer.step()?;
        println!(
            "step {:>2} | return {:+.2} | episode-ctx {:>5.1} | bucket {} | \
             rollout {:>5.2}s | exp-prep {:>5.2}s | dispatch(sim) {:>7.4}s | \
             update {:>5.2}s",
            rec.step,
            rec.mean_return,
            rec.mean_episode_ctx,
            rec.bucket,
            rec.rollout_seconds,
            rec.exp_prep_seconds,
            rec.dispatch_seconds,
            rec.train_seconds,
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
