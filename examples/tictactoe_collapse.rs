//! **Fig. 1 reproduction (real model)** — train the actual PJRT policy on
//! Tic-Tac-Toe under (A) a hard context limit and (B) EARL's dynamic
//! buckets, and print the three curves of the paper's figure:
//! (a) turn-level context, (b) episode-level context + truncation rate,
//! (c) average return.
//!
//! The paper's setting: a 4B model, max context 8,192, ~3 turns/episode;
//! context grows during training until it hits the limit around step 13,
//! truncated ("low-quality") rollouts poison the batch, and the return
//! collapses after step 15. Here the model is the AOT "small" preset and
//! the limit is scaled to its episode lengths: reasoning tokens are
//! allowed to grow (high entropy bonus + long per-turn budget), and the
//! hard limit sits where mid-training episodes land.
//!
//!     cargo run --release --example tictactoe_collapse -- [steps]

use anyhow::Result;

use earl::config::TrainConfig;
use earl::coordinator::Trainer;
use earl::rollout::LimitPolicy;

fn run(label: &str, limit: LimitPolicy, steps: u64) -> Result<Vec<(f64, f64, f64, f64)>> {
    let mut cfg = TrainConfig::default();
    cfg.artifacts_dir =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.steps = steps;
    cfg.seed = 11;
    cfg.rollout.limit = limit;
    // Encourage long reasoning so context grows during training (the
    // paper's response-length growth): generous per-turn budget + strong
    // entropy bonus over the think-token vocabulary.
    cfg.rollout.max_response_tokens = 10;
    cfg.hp.ent_coef = 0.08;
    cfg.hp.lr = 2e-3;
    cfg.hp.kl_coef = 0.0;

    eprintln!("\n### {label} ({limit:?}) ###");
    let mut trainer = Trainer::new(cfg)?;
    let mut out = Vec::new();
    for _ in 0..steps {
        let rec = trainer.step()?;
        eprintln!(
            "  step {:>3}  turn-ctx {:>5.1}  ep-ctx {:>6.1}  trunc {:>5.1}%  \
             return {:+.3}",
            rec.step,
            rec.mean_turn_ctx,
            rec.mean_episode_ctx,
            rec.truncation_rate * 100.0,
            rec.mean_return,
        );
        out.push((
            rec.mean_turn_ctx,
            rec.mean_episode_ctx,
            rec.truncation_rate,
            rec.mean_return,
        ));
    }
    Ok(out)
}

fn mean_tail(xs: &[(f64, f64, f64, f64)], k: usize, f: impl Fn(&(f64, f64, f64, f64)) -> f64) -> f64 {
    let tail = &xs[xs.len().saturating_sub(k)..];
    tail.iter().map(&f).sum::<f64>() / tail.len() as f64
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    // (A) the paper's baseline: hard limit sized to bite mid-training.
    // The "small" model's tic-tac-toe episodes run ~60–80 tokens with
    // terse responses and grow well past 100 as reasoning lengthens.
    let baseline = run("A: hard context limit (Fig. 1 baseline)",
                       LimitPolicy::Hard(96), steps)?;
    // (B) EARL: dynamic buckets up to the largest compiled context.
    let earl = run("B: EARL dynamic buckets", LimitPolicy::Buckets, steps)?;

    println!("\n=== Fig. 1 summary (last 10 steps) ===");
    println!(
        "{:<28} {:>12} {:>12}",
        "", "A: hard-limit", "B: EARL"
    );
    let rows: [(&str, fn(&(f64, f64, f64, f64)) -> f64); 4] = [
        ("turn-level context", |r| r.0),
        ("episode-level context", |r| r.1),
        ("truncation rate", |r| r.2),
        ("average return", |r| r.3),
    ];
    for (name, f) in rows {
        println!(
            "{name:<28} {:>12.2} {:>12.2}",
            mean_tail(&baseline, 10, f),
            mean_tail(&earl, 10, f),
        );
    }

    let a_ret = mean_tail(&baseline, 10, |r| r.3);
    let b_ret = mean_tail(&earl, 10, |r| r.3);
    let a_trunc = mean_tail(&baseline, 10, |r| r.2);
    println!(
        "\npaper Fig. 1: the hard-limit run truncates and its return \
         collapses; EARL keeps training stable.\n\
         ours: baseline trunc {:.0}% return {:+.2}; EARL return {:+.2}",
        a_trunc * 100.0,
        a_ret,
        b_ret
    );
    Ok(())
}
