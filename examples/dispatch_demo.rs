//! **Fig. 4 walkthrough** — the Data Dispatcher on real TCP sockets:
//! plan the ref-logprob exchange two ways (single-controller baseline vs
//! EARL all-to-all), execute both over loopback with emulated 2.5 Gbps
//! NICs, and verify the plans deliver identical data placements.
//!
//!     cargo run --release --example dispatch_demo -- [workers] [mib]

use anyhow::Result;

use earl::dispatch::{
    plan_alltoall, plan_centralized, satisfies, tcp::execute_plan_tcp_rated,
    DataLayout,
};
use earl::util::bytes::{human_bytes, human_duration};

fn main() -> Result<()> {
    let workers: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mib: u64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let nic = Some(312.5e6); // 2.5 Gbps per worker

    // The ExpPrep stage produced ref-logprobs round-robin; the trainers
    // want contiguous blocks (a full reshard, as after a parallelism
    // switch).
    let items = workers * workers;
    let producer = DataLayout::round_robin(items, workers);
    let consumer = DataLayout::blocked(items, workers);
    let item_bytes = (mib << 20) / workers as u64;

    let base = plan_centralized(&producer, &consumer, item_bytes, 0);
    let earl = plan_alltoall(&producer, &consumer, item_bytes);

    println!("== dispatch plans: {workers} workers, {mib} MiB/worker ==");
    println!(
        "baseline: {} transfers in {} phases, {} total",
        base.n_transfers(),
        base.phases.len(),
        human_bytes(base.total_bytes()),
    );
    println!(
        "EARL:     {} transfers in {} phase,  {} total",
        earl.n_transfers(),
        earl.phases.len(),
        human_bytes(earl.total_bytes()),
    );

    // Content equivalence: both must realize the consumer layout.
    assert!(satisfies(&base, &producer, &consumer));
    assert!(satisfies(&earl, &producer, &consumer));
    println!("both plans deliver the identical item→worker placement ✓");

    println!("\nexecuting on loopback TCP (2.5 Gbps emulated NICs)...");
    let tb = execute_plan_tcp_rated(&base, workers, nic)?;
    let te = execute_plan_tcp_rated(&earl, workers, nic)?;
    println!(
        "baseline: {}  (gather {} + scatter {})",
        human_duration(tb.seconds),
        human_duration(tb.phase_seconds[0]),
        human_duration(tb.phase_seconds[1]),
    );
    println!("EARL:     {}", human_duration(te.seconds));
    println!(
        "latency reduction: {:.1}x  (paper Fig. 4: 9.7–11.2x)",
        tb.seconds / te.seconds
    );
    Ok(())
}
