"""L2 correctness: transformer forward, logprobs, and the fused RL
train step — shapes, gradients, and learning behaviour."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.PRESETS["tiny"]
B, T = 4, 64


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def _tokens(key, b=B, t=T):
    return jax.random.randint(key, (b, t), 0, CFG.vocab, jnp.int32)


class TestParamSpec:
    def test_order_stable(self):
        names = [n for n, _ in M.param_spec(CFG)]
        assert names == ["embed", "ln1", "wq", "wk", "wv", "wo",
                         "ln2", "w1", "w3", "w2", "lnf"]

    def test_init_matches_spec(self, params):
        for p, (name, shape) in zip(params, M.param_spec(CFG)):
            assert p.shape == shape, name
            assert p.dtype == jnp.float32, name

    def test_init_deterministic(self):
        a = M.init_params(CFG, seed=0)
        b = M.init_params(CFG, seed=0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_init_seed_sensitivity(self):
        a = M.init_params(CFG, seed=0)
        b = M.init_params(CFG, seed=1)
        assert not np.allclose(a[0], b[0])

    def test_n_params_counts(self):
        assert CFG.n_params() == sum(
            math.prod(s) for _, s in M.param_spec(CFG))


class TestForward:
    def test_logits_shape(self, params):
        toks = _tokens(jax.random.PRNGKey(0))
        (lg,) = M.logits_fn(CFG, *params, toks)
        assert lg.shape == (B, T, CFG.vocab)
        assert np.isfinite(np.asarray(lg)).all()

    def test_kernel_vs_ref_forward(self, params):
        """Pallas-kernel model == reference-attention model."""
        toks = _tokens(jax.random.PRNGKey(1))
        a = M.forward(CFG, params, toks, use_kernel=True)
        b = M.forward(CFG, params, toks, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)

    def test_causal(self, params):
        """Changing a suffix token must not change earlier logits."""
        toks = _tokens(jax.random.PRNGKey(2))
        toks2 = toks.at[:, T - 1].set((toks[:, T - 1] + 1) % CFG.vocab)
        a = M.forward(CFG, params, toks)
        b = M.forward(CFG, params, toks2)
        np.testing.assert_allclose(np.asarray(a[:, :T - 1]),
                                   np.asarray(b[:, :T - 1]), atol=1e-5)

    def test_logprobs_are_logprobs(self, params):
        toks = _tokens(jax.random.PRNGKey(3))
        (lp,) = M.logprobs_fn(CFG, *params, toks)
        assert lp.shape == (B, T)
        lp = np.asarray(lp)
        assert (lp[:, 1:] <= 1e-6).all()   # log-probabilities
        assert (lp[:, 0] == 0.0).all()     # position 0 unscored

    def test_logprobs_consistent_with_logits(self, params):
        toks = _tokens(jax.random.PRNGKey(4))
        (lg,) = M.logits_fn(CFG, *params, toks)
        (lp,) = M.logprobs_fn(CFG, *params, toks)
        want = ref.token_logprobs(lg, toks)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)


def _train_args(params, key, adv_scale=1.0):
    n = len(params)
    zeros = [jnp.zeros_like(p) for p in params]
    toks = _tokens(key)
    mask = jnp.ones((B, T), jnp.float32).at[:, :4].set(0.0)
    adv = adv_scale * jax.random.normal(key, (B, T), jnp.float32)
    ref_lp = M.logprobs_fn(CFG, *params, toks)[0]
    return (*params, *zeros, *zeros, toks, mask, adv, ref_lp,
            jnp.float32(1.0), jnp.float32(1e-3),
            jnp.float32(0.0), jnp.float32(0.0)), n


class TestTrainStep:
    def test_output_arity_and_shapes(self, params):
        args, n = _train_args(params, jax.random.PRNGKey(0))
        out = M.train_step_fn(CFG, *args)
        assert len(out) == 3 * n + 4
        for i, p in enumerate(params):
            assert out[i].shape == p.shape
            assert out[n + i].shape == p.shape
            assert out[2 * n + i].shape == p.shape
        for s in out[3 * n:]:
            assert s.shape == ()

    def test_zero_advantage_zero_pg(self, params):
        args, n = _train_args(params, jax.random.PRNGKey(1), adv_scale=0.0)
        out = M.train_step_fn(CFG, *args)
        pg = float(out[3 * n + 1])
        assert abs(pg) < 1e-6

    def test_kl_zero_against_self(self, params):
        """ref model == policy → k3 KL estimate is ~0."""
        args, n = _train_args(params, jax.random.PRNGKey(2))
        out = M.train_step_fn(CFG, *args)
        kl = float(out[3 * n + 2])
        assert abs(kl) < 1e-5

    def test_params_move(self, params):
        args, n = _train_args(params, jax.random.PRNGKey(3))
        out = M.train_step_fn(CFG, *args)
        moved = any(not np.allclose(np.asarray(out[i]), np.asarray(params[i]))
                    for i in range(n))
        assert moved

    def test_policy_gradient_reinforces(self, params):
        """Positive advantage on chosen tokens raises their logprob."""
        key = jax.random.PRNGKey(4)
        toks = _tokens(key)
        mask = jnp.ones((B, T), jnp.float32).at[:, 0].set(0.0)
        adv = jnp.ones((B, T), jnp.float32)
        ref_lp = M.logprobs_fn(CFG, *params, toks)[0]
        zeros = [jnp.zeros_like(p) for p in params]
        n = len(params)
        ps = list(params)
        ms, vs = zeros, zeros
        before = float(jnp.sum(ref_lp * mask))
        for step in range(5):
            out = M.train_step_fn(
                CFG, *ps, *ms, *vs, toks, mask, adv, ref_lp,
                jnp.float32(step + 1), jnp.float32(3e-3),
                jnp.float32(0.0), jnp.float32(0.0))
            ps, ms, vs = (list(out[:n]), list(out[n:2 * n]),
                          list(out[2 * n:3 * n]))
        after = float(jnp.sum(M.logprobs_fn(CFG, *ps, toks)[0] * mask))
        assert after > before

    def test_mask_gates_gradient(self, params):
        """With an all-zero mask, params must not move."""
        key = jax.random.PRNGKey(5)
        toks = _tokens(key)
        mask = jnp.zeros((B, T), jnp.float32)
        adv = jnp.ones((B, T), jnp.float32)
        ref_lp = M.logprobs_fn(CFG, *params, toks)[0]
        zeros = [jnp.zeros_like(p) for p in params]
        n = len(params)
        out = M.train_step_fn(
            CFG, *params, *zeros, *zeros, toks, mask, adv, ref_lp,
            jnp.float32(1.0), jnp.float32(1e-2),
            jnp.float32(0.0), jnp.float32(0.0))
        for i in range(n):
            np.testing.assert_allclose(np.asarray(out[i]),
                                       np.asarray(params[i]), atol=1e-7)

    def test_kl_pulls_toward_reference(self, params):
        """With only the KL term active, policy logprobs approach ref."""
        key = jax.random.PRNGKey(6)
        toks = _tokens(key)
        mask = jnp.ones((B, T), jnp.float32).at[:, 0].set(0.0)
        adv = jnp.zeros((B, T), jnp.float32)
        ref_lp = M.logprobs_fn(CFG, *params, toks)[0]
        # Perturb the policy away from the reference.
        pert = [p + 0.02 * jax.random.normal(jax.random.PRNGKey(7 + i),
                                             p.shape)
                for i, p in enumerate(params)]
        zeros = [jnp.zeros_like(p) for p in params]
        n = len(params)

        def kl_of(ps):
            lp = M.logprobs_fn(CFG, *ps, toks)[0]
            r = ref_lp - lp
            return float(jnp.sum((jnp.exp(r) - r - 1) * mask)
                         / jnp.sum(mask))

        k0 = kl_of(pert)
        ps, ms, vs = list(pert), zeros, zeros
        for step in range(8):
            out = M.train_step_fn(
                CFG, *ps, *ms, *vs, toks, mask, adv, ref_lp,
                jnp.float32(step + 1), jnp.float32(3e-3),
                jnp.float32(0.0), jnp.float32(1.0))
            ps, ms, vs = (list(out[:n]), list(out[n:2 * n]),
                          list(out[2 * n:3 * n]))
        assert kl_of(ps) < k0


class TestRoPE:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 16, 8))
        y = M._rope(x, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_rope_position_zero_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 4, 8))
        y = M._rope(x, 10_000.0)
        np.testing.assert_allclose(np.asarray(y[:, :, 0]),
                                   np.asarray(x[:, :, 0]), atol=1e-6)
