"""L1 correctness: Pallas flash-attention vs the pure-jnp oracle.

This is the core numeric signal for the whole stack: the same kernel
lowers into every HLO artifact the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention
from compile.kernels import ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _check(b, h, t, d, dtype=jnp.float32, block_q=64, block_k=64,
           atol=2e-5, rtol=2e-5, seed=0):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(k0, (b, h, t, d), dtype)
    k = _rand(k1, (b, h, t, d), dtype)
    v = _rand(k2, (b, h, t, d), dtype)
    got = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=rtol)


class TestBasic:
    def test_small(self):
        _check(2, 2, 64, 32)

    def test_single_block(self):
        _check(1, 1, 64, 16)

    def test_multi_block(self):
        _check(2, 4, 256, 32)

    def test_block_q_ne_block_k(self):
        _check(1, 2, 256, 32, block_q=128, block_k=64)
        _check(1, 2, 256, 32, block_q=64, block_k=128)

    def test_seq_equals_bucket_sizes(self):
        for t in (128, 256, 512):
            _check(1, 2, t, 32)

    def test_batch_one_head_one(self):
        _check(1, 1, 128, 32)

    def test_bf16_inputs(self):
        # bf16 in, f32 accumulate; tolerance scaled to bf16 resolution.
        _check(1, 2, 128, 32, dtype=jnp.bfloat16, atol=2e-2, rtol=2e-2)

    def test_causality(self):
        """Perturbing future tokens must not change earlier outputs."""
        key = jax.random.PRNGKey(3)
        k0, k1, k2 = jax.random.split(key, 3)
        b, h, t, d = 1, 2, 128, 32
        q = _rand(k0, (b, h, t, d), jnp.float32)
        k = _rand(k1, (b, h, t, d), jnp.float32)
        v = _rand(k2, (b, h, t, d), jnp.float32)
        out1 = flash_attention(q, k, v)
        k2_ = k.at[:, :, t // 2:, :].set(9.0)
        v2_ = v.at[:, :, t // 2:, :].set(-9.0)
        out2 = flash_attention(q, k2_, v2_)
        np.testing.assert_allclose(out1[:, :, :t // 2],
                                   out2[:, :, :t // 2], atol=1e-6)

    def test_first_position_is_v0(self):
        """Row 0 attends only to itself: out[0] == v[0]."""
        _b, _h, t, d = 1, 1, 64, 16
        key = jax.random.PRNGKey(4)
        q, k, v = (_rand(s, (1, 1, t, d), jnp.float32)
                   for s in jax.random.split(key, 3))
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-6)

    def test_large_magnitude_stability(self):
        """Online softmax must survive large score magnitudes."""
        b, h, t, d = 1, 1, 128, 32
        key = jax.random.PRNGKey(5)
        q, k, v = (_rand(s, (b, h, t, d), jnp.float32) * 30.0
                   for s in jax.random.split(key, 3))
        got = flash_attention(q, k, v)
        want = ref.causal_attention(q, k, v)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-3)


# Hypothesis sweep: shapes and dtypes, always vs the oracle. Sequence
# lengths are sampled as multiples of the block size (bucketed contexts —
# the only shapes the AOT path ever emits).
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    block=st.sampled_from([32, 64]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_sweep(b, h, t_blocks, d, block, dtype, seed):
    t = t_blocks * block
    tol = 2e-5 if dtype == "float32" else 3e-2
    _check(b, h, t, d, dtype=jnp.dtype(dtype), block_q=block, block_k=block,
           atol=tol, rtol=tol, seed=seed)


class TestBackward:
    """The hand-written Pallas backward kernels vs jax.grad of the oracle."""

    def _grad_check(self, b, h, t, d, block_q=64, block_k=64, seed=0,
                    atol=1e-4, rtol=1e-4):
        k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = _rand(k0, (b, h, t, d), jnp.float32)
        k = _rand(k1, (b, h, t, d), jnp.float32)
        v = _rand(k2, (b, h, t, d), jnp.float32)
        co = _rand(k3, (b, h, t, d), jnp.float32)  # cotangent direction

        def loss_kernel(q, k, v):
            return jnp.sum(flash_attention(
                q, k, v, block_q=block_q, block_k=block_k) * co)

        def loss_ref(q, k, v):
            return jnp.sum(ref.causal_attention(q, k, v) * co)

        g_got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        g_want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for got, want, name in zip(g_got, g_want, "qkv"):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=atol, rtol=rtol,
                err_msg=f"d{name}")

    def test_grads_single_block(self):
        self._grad_check(1, 1, 64, 16)

    def test_grads_multi_block(self):
        self._grad_check(2, 2, 256, 32)

    def test_grads_uneven_blocks(self):
        self._grad_check(1, 2, 256, 32, block_q=128, block_k=64)
        self._grad_check(1, 2, 256, 32, block_q=64, block_k=128)

    def test_grads_bucket_sizes(self):
        for t in (128, 256):
            self._grad_check(1, 2, t, 32, seed=t)

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 2),
        h=st.integers(1, 2),
        t_blocks=st.integers(1, 3),
        d=st.sampled_from([8, 16, 32]),
        block=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_grads_sweep(self, b, h, t_blocks, d, block, seed):
        self._grad_check(b, h, t_blocks * block, d, block_q=block,
                         block_k=block, seed=seed, atol=3e-4, rtol=3e-4)


def test_logprobs_oracle_manual():
    """token_logprobs against a hand-computed tiny case."""
    logits = jnp.array([[[0.0, 0.0], [2.0, 0.0], [0.0, 1.0]]])  # (1,3,2)
    tokens = jnp.array([[1, 0, 1]], jnp.int32)
    lp = ref.token_logprobs(logits, tokens)
    assert lp.shape == (1, 3)
    assert float(lp[0, 0]) == 0.0
    # position 1: token 0 under logits[0] = log softmax([0,0])[0] = log .5
    np.testing.assert_allclose(float(lp[0, 1]), np.log(0.5), rtol=1e-6)
    # position 2: token 1 under logits[1] = [2,0] → log(e^0/(e^2+e^0))
    np.testing.assert_allclose(
        float(lp[0, 2]), -np.log(1 + np.e**2), rtol=1e-6)


def test_entropy_uniform():
    v = 8
    logits = jnp.zeros((2, 4, v))
    ent = ref.entropy(logits)
    np.testing.assert_allclose(np.asarray(ent), np.log(v), rtol=1e-6)
