"""AOT pipeline: lowering, manifest integrity, and the HLO-text contract
with the rust runtime."""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

CFG = M.PRESETS["tiny"]


class TestLowering:
    def test_logits_hlo_text_wellformed(self):
        text = aot.lower_function(CFG, "logits", 2, 64)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # tokens input and logits output shapes appear
        assert "s32[2,64]" in text
        assert f"f32[2,64,{CFG.vocab}]" in text

    def test_bucket_changes_shapes(self):
        t64 = aot.lower_function(CFG, "logprobs", 2, 64)
        t128 = aot.lower_function(CFG, "logprobs", 2, 128)
        assert "s32[2,64]" in t64 and "s32[2,128]" in t128
        assert t64 != t128

    def test_train_step_arity(self):
        sig = aot.io_signature(CFG, "train_step", 2, 64)
        n = len(M.param_spec(CFG))
        assert sig["inputs"][0] == f"params[{n}]"
        assert sig["outputs"][-1] == "entropy:f32"

    def test_unknown_function_rejected(self):
        with pytest.raises(ValueError):
            aot.lower_function(CFG, "nope", 2, 64)

    def test_hlo_contains_no_custom_call(self):
        """interpret=True must lower Pallas to plain HLO — a Mosaic
        custom-call would be unloadable by the CPU PJRT client."""
        text = aot.lower_function(CFG, "logits", 2, 64)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


class TestEndToEndArtifacts:
    @pytest.fixture(scope="class")
    def outdir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        argv = sys.argv
        sys.argv = [
            "aot", "--preset", "tiny", "--out-dir", str(out),
            "--buckets", "32,64", "--batch", "2",
        ]
        try:
            aot.main()
        finally:
            sys.argv = argv
        return out

    def test_manifest_complete(self, outdir):
        m = json.loads((outdir / "manifest.json").read_text())
        assert m["version"] == 1
        assert m["buckets"] == [32, 64]
        assert m["batch"] == 2
        assert len(m["artifacts"]) == 6  # 3 fns x 2 buckets
        for a in m["artifacts"]:
            assert (outdir / a["file"]).exists(), a["file"]
        names = [p["name"] for p in m["param_spec"]]
        assert names[0] == "embed" and names[-1] == "lnf"

    def test_params_blob_matches_spec(self, outdir):
        m = json.loads((outdir / "manifest.json").read_text())
        blob = (outdir / "params.bin").read_bytes()
        total = sum(math.prod(p["shape"]) for p in m["param_spec"])
        assert len(blob) == total * 4
        assert m["model"]["n_params"] == total
        # Blob reproduces init_params exactly (little-endian f32).
        params = M.init_params(CFG, seed=m["seed"])
        flat = np.concatenate([np.asarray(p).ravel() for p in params])
        got = np.frombuffer(blob, dtype="<f4")
        np.testing.assert_array_equal(got, flat.astype("<f4"))

    def test_artifact_checksums(self, outdir):
        import hashlib
        m = json.loads((outdir / "manifest.json").read_text())
        for a in m["artifacts"]:
            text = (outdir / a["file"]).read_text()
            assert hashlib.sha256(text.encode()).hexdigest()[:16] == a["sha256"]


class TestNumericalContract:
    """The AOT'd computation must equal the eager computation — this is
    the python side of the rust integration test's consistency check."""

    def test_lowered_logits_match_eager(self):
        params = M.init_params(CFG, seed=0)
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (2, 64), 0, CFG.vocab, jnp.int32)
        eager = M.logits_fn(CFG, *params, tokens)[0]
        compiled = jax.jit(lambda *a: M.logits_fn(CFG, *a))(*params, tokens)[0]
        np.testing.assert_allclose(
            np.asarray(eager), np.asarray(compiled), atol=1e-5, rtol=1e-5)

    def test_logprobs_position_zero_is_zero(self):
        params = M.init_params(CFG, seed=0)
        tokens = jnp.zeros((2, 64), jnp.int32)
        lp = M.logprobs_fn(CFG, *params, tokens)[0]
        assert float(jnp.abs(lp[:, 0]).max()) == 0.0
