"""Layer-2 JAX model: GPT-style decoder-only transformer + RL train step.

This is the policy / reference model of the agentic RL loop. The attention
hot-spot calls the Layer-1 Pallas kernel (``kernels.attention``), so the
kernel lowers into the same HLO artifact the rust runtime executes.

Everything is expressed over a *flat, ordered tuple* of parameter tensors
(see :func:`param_spec`) rather than a nested pytree: the rust coordinator
marshals PJRT literals positionally, so the order here is the ABI between
the python compile path and the rust hot path. ``manifest.json`` (written
by ``aot.py``) records the same order.

Architecture: token embedding (tied LM head), per-layer [RMSNorm → MHA
(RoPE, flash-attention kernel) → residual, RMSNorm → SwiGLU MLP →
residual], final RMSNorm. Per-layer weights are stacked on a leading
``n_layers`` axis and consumed with ``lax.scan`` to keep the lowered HLO
compact (one layer body, not ``n_layers`` copies).

Exported entry points (lowered per context bucket by ``aot.py``):

* :func:`logits_fn` — full-sequence logits; rollout sampling happens in
  rust on top of these.
* :func:`logprobs_fn` — per-token log-probabilities; used for the policy's
  behaviour log-probs and for the *reference model* whose tensors the
  Data Dispatcher ships between stages (paper §3.3).
* :func:`train_step_fn` — REINFORCE policy-gradient loss with KL-to-
  reference penalty and entropy bonus, grads, and a fused Adam update.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model hyper-parameters (the ABI with the rust runtime)."""

    vocab: int = 64
    d_model: int = 256
    n_layers: int = 6
    n_heads: int = 8
    d_ff: int = 512
    max_seq: int = 512          # largest context bucket
    rope_theta: float = 10_000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        import math
        return sum(int(math.prod(s)) for _, s in param_spec(self))


# Presets selectable from `aot.py --preset`. "small" is the CPU-tractable
# end-to-end RL default; "tiny" keeps pytest fast; "medium" is for scaling
# studies; "100m" matches the paper-scale ratio (artifact-size / compile
# studies, not e2e CPU training).
PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=2,
                        d_ff=128, max_seq=128),
    "small": ModelConfig(vocab=64, d_model=128, n_layers=4, n_heads=4,
                         d_ff=384, max_seq=512),
    "medium": ModelConfig(),
    "100m": ModelConfig(vocab=4096, d_model=768, n_layers=12, n_heads=12,
                        d_ff=2304, max_seq=1024),
}


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the positional ABI for PJRT literals."""
    L, D, F, H = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_heads
    return [
        ("embed", (cfg.vocab, D)),
        ("ln1", (L, D)),
        ("wq", (L, D, D)),
        ("wk", (L, D, D)),
        ("wv", (L, D, D)),
        ("wo", (L, D, D)),
        ("ln2", (L, D)),
        ("w1", (L, D, F)),
        ("w3", (L, D, F)),
        ("w2", (L, F, D)),
        ("lnf", (D,)),
    ]


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Deterministic init, returned in :func:`param_spec` order."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.startswith("ln"):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = fan_in ** -0.5
            # Scale residual-writing projections down by sqrt(2L) (GPT-2).
            if name in ("wo", "w2"):
                std /= (2 * cfg.n_layers) ** 0.5
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


def _rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, theta: float):
    """Rotary position embedding over (batch, heads, seq, head_dim)."""
    b, h, t, d = x.shape
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]  # (t, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _block(cfg: ModelConfig, x, lp, *, use_kernel: bool):
    """One transformer block. ``lp``: dict of this layer's tensors."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim

    y = _rmsnorm(x, lp["ln1"])
    q = (y @ lp["wq"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = (y @ lp["wk"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = (y @ lp["wv"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    q = _rope(q, cfg.rope_theta)
    k = _rope(k, cfg.rope_theta)
    attn = flash_attention(q, k, v) if use_kernel \
        else kref.causal_attention(q, k, v)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + attn @ lp["wo"]

    y = _rmsnorm(x, lp["ln2"])
    gate = jax.nn.silu(y @ lp["w1"])
    x = x + (gate * (y @ lp["w3"])) @ lp["w2"]
    return x


def forward(cfg: ModelConfig, params: List[jnp.ndarray], tokens,
            *, use_kernel: bool = True):
    """Full-sequence logits ``(batch, seq, vocab)``.

    ``tokens``: ``(batch, seq)`` int32. Padding is by trailing pad tokens;
    causality keeps them from affecting earlier positions.
    """
    names = [n for n, _ in param_spec(cfg)]
    p = dict(zip(names, params))
    x = p["embed"][tokens]  # (b, t, d)

    layer_names = ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w3", "w2"]
    stacked = {n: p[n] for n in layer_names}

    def step(x, layer):
        return _block(cfg, x, layer, use_kernel=use_kernel), None

    x, _ = jax.lax.scan(step, x, stacked)
    x = _rmsnorm(x, p["lnf"])
    return x @ p["embed"].T


def logits_fn(cfg: ModelConfig, *args, use_kernel: bool = True):
    """AOT entry: ``(*params, tokens) -> (logits,)``."""
    params, tokens = list(args[:-1]), args[-1]
    return (forward(cfg, params, tokens, use_kernel=use_kernel),)


def logprobs_fn(cfg: ModelConfig, *args, use_kernel: bool = True):
    """AOT entry: ``(*params, tokens) -> (per-token logprobs,)``.

    Output ``(batch, seq)``: position ``t`` holds log p(tokens[t] |
    tokens[<t]); position 0 is 0.
    """
    params, tokens = list(args[:-1]), args[-1]
    logits = forward(cfg, params, tokens, use_kernel=use_kernel)
    return (kref.token_logprobs(logits, tokens),)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def rl_loss(cfg: ModelConfig, params, tokens, mask, advantages,
            ref_logprobs, ent_coef, kl_coef, *, use_kernel: bool = True):
    """REINFORCE loss with KL-to-reference penalty and entropy bonus.

    mask: 1.0 at *agent-generated* token positions (the only positions the
    policy gradient flows through); advantages: per-token advantage
    (REINFORCE: the whitened episode return broadcast over its tokens);
    ref_logprobs: the reference model's per-token logprobs — the tensor
    the Data Dispatcher ships from the ExpPrep stage (paper §3.3).

    Returns (loss, (pg, kl, entropy)).
    """
    logits = forward(cfg, params, tokens, use_kernel=use_kernel)
    logp = kref.token_logprobs(logits, tokens)
    denom = jnp.maximum(jnp.sum(mask), 1.0)

    pg = -jnp.sum(logp * advantages * mask) / denom
    # Schulman k3 estimator: unbiased, non-negative.
    lr_ratio = ref_logprobs - logp
    kl = jnp.sum((jnp.exp(lr_ratio) - lr_ratio - 1.0) * mask) / denom
    ent = jnp.sum(kref.entropy(logits)[:, :-1] * mask[:, 1:]) / denom

    loss = pg + kl_coef * kl - ent_coef * ent
    return loss, (pg, kl, ent)


def train_step_fn(cfg: ModelConfig, *args, use_kernel: bool = True):
    """AOT entry — fused loss + grad + Adam update.

    Positional signature (n = len(param_spec)):
      args[0:n]        params
      args[n:2n]       Adam m
      args[2n:3n]      Adam v
      then: tokens (b,t) i32, mask (b,t) f32, advantages (b,t) f32,
            ref_logprobs (b,t) f32, step f32 scalar (1-based), lr f32,
            ent_coef f32, kl_coef f32.
    Returns: (*new_params, *new_m, *new_v, loss, pg, kl, entropy).
    """
    n = len(param_spec(cfg))
    params = list(args[:n])
    m = list(args[n:2 * n])
    v = list(args[2 * n:3 * n])
    (tokens, mask, advantages, ref_logprobs,
     step, lr, ent_coef, kl_coef) = args[3 * n:]

    def loss_of(ps):
        return rl_loss(cfg, ps, tokens, mask, advantages, ref_logprobs,
                       ent_coef, kl_coef, use_kernel=use_kernel)

    (loss, (pg, kl, ent)), grads = jax.value_and_grad(
        loss_of, has_aux=True)(params)

    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    new_p, new_m, new_v = [], [], []
    for p_i, m_i, v_i, g_i in zip(params, m, v, grads):
        m_n = ADAM_B1 * m_i + (1.0 - ADAM_B1) * g_i
        v_n = ADAM_B2 * v_i + (1.0 - ADAM_B2) * jnp.square(g_i)
        upd = (m_n / bc1) / (jnp.sqrt(v_n / bc2) + ADAM_EPS)
        new_p.append(p_i - lr * upd)
        new_m.append(m_n)
        new_v.append(v_n)

    return (*new_p, *new_m, *new_v, loss, pg, kl, ent)
