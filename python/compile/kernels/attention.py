"""Layer-1 Pallas kernels: blocked causal flash attention (fwd + bwd).

This is the compute hot-spot of both the Rollout stage (decode scoring)
and the Model-Update stage (fwd/bwd) — exactly the cost that grows with
context length and that EARL's Parallelism Selector reacts to. The
backward pass is also hand-written as Pallas kernels (dq and dk/dv
passes, flash-attention style: recompute P from the saved row-logsumexp
instead of materializing the O(T^2) score matrix), wired in via
``jax.custom_vjp`` so the fused ``train_step`` HLO artifact contains the
kernels end-to-end.

Hardware adaptation (paper targets CUDA GPUs, we target the TPU-shaped
Pallas model, run under ``interpret=True`` on CPU):

* instead of a threadblock/shared-memory tiling, the kernels tile for
  VMEM via ``BlockSpec``: each grid step holds one Q (or KV) tile plus
  the streamed counterpart rows for its (batch, head) slice, walking them
  in chunks with an online-softmax accumulator;
* matmul accumulation is f32 (MXU-style), block edges are multiples of
  the lane width where the shape allows.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers the
kernels to plain HLO so the same artifacts run on the rust CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes. VMEM estimate per fwd grid step (f32):
#   q: BQ*d, k/v chunk: 2*BK*d, scores: BQ*BK, acc: BQ*d, m/l: 2*BQ
# With BQ=BK=64, d=32: ~49 KiB — far under the ~16 MiB VMEM budget; the
# limit on block growth is the score tile (BQ*BK) staying MXU-aligned.
# See DESIGN.md §Perf and EXPERIMENTS.md §Perf for the block-shape sweep.
BLOCK_Q = 64
BLOCK_K = 64

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                block_k: int, scale: float):
    """One grid step: one (batch*head, q-block) tile.

    Block shapes (leading grid-collapsed axis of extent 1):
      q_ref: (1, block_q, d); k_ref/v_ref: (1, seq, d);
      o_ref: (1, block_q, d); lse_ref: (1, block_q).
    """
    block_q = q_ref.shape[1]
    seq = k_ref.shape[1]
    d = q_ref.shape[2]

    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k_all = k_ref[0]                                   # (seq, d)
    v_all = v_ref[0]
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k = jax.lax.dynamic_slice(
            k_all, (j * block_k, 0), (block_k, d)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_all, (j * block_k, 0), (block_k, d)).astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)          # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        # Rows where everything so far is masked: m_new == NEG_INF, and
        # exp(NEG_INF - NEG_INF) = 1 would pollute l. Zero those rows.
        p = jnp.where(m_new > _NEG_INF / 2, p, 0.0)
        alpha = jnp.where(m_prev > _NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    # Causality: KV blocks strictly after this Q tile contribute nothing;
    # bound the walk at the last block that intersects the tile's rows.
    n_live = jnp.minimum((iq + 1) * block_q + block_k - 1, seq) // block_k
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    # Causal rows always see at least themselves (l >= 1); guard anyway.
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l_safe).astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(l_safe))[:, 0]


def _flash_fwd(q, k, v, block_q: int, block_k: int):
    """Returns (o, lse) with q/k/v: (bh, t, d); lse: (bh, t) f32."""
    bh, t, d = q.shape
    scale = 1.0 / (d ** 0.5)
    grid = (bh, t // block_q)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, iq: (bh_, iq, 0)),
            pl.BlockSpec((1, t, d), lambda bh_, iq: (bh_, 0, 0)),
            pl.BlockSpec((1, t, d), lambda bh_, iq: (bh_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, iq: (bh_, iq, 0)),
            pl.BlockSpec((1, block_q), lambda bh_, iq: (bh_, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), q.dtype),
            jax.ShapeDtypeStruct((bh, t), jnp.float32),
        ],
        interpret=True,  # mandatory for CPU-PJRT execution (see module doc)
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------
# Standard flash-attention backward split into two passes so each output
# tile has a single writer (no cross-grid-step accumulation):
#   dq pass: grid over Q blocks, streams KV;   dq = scale * dS @ K
#   dkv pass: grid over KV blocks, streams Q;  dk = scale * dS^T Q,
#                                              dv = P^T dO
# with P recomputed from the saved row-logsumexp:
#   P = exp(scale*QK^T - lse),  dS = P * (dO V^T - delta),
#   delta_i = sum_d dO_id * O_id  (precomputed outside the kernels).

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               block_k: int, scale: float):
    block_q = q_ref.shape[1]
    seq = k_ref.shape[1]
    d = q_ref.shape[2]

    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]        # (bq, 1)
    delta = delta_ref[0][:, None]    # (bq, 1)
    k_all, v_all = k_ref[0], v_ref[0]
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(j, acc):
        k = jax.lax.dynamic_slice(
            k_all, (j * block_k, 0), (block_k, d)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_all, (j * block_k, 0), (block_k, d)).astype(jnp.float32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        p = jnp.exp(s - lse)                                 # (bq, bk)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        return acc + jnp.dot(ds, k, preferred_element_type=jnp.float32)

    n_live = jnp.minimum((iq + 1) * block_q + block_k - 1, seq) // block_k
    acc = jax.lax.fori_loop(
        0, n_live, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[0] = (scale * acc).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, block_q: int, scale: float):
    seq = q_ref.shape[1]
    block_k = k_ref.shape[1]
    d = q_ref.shape[2]

    jk = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    q_all, do_all = q_ref[0], do_ref[0]
    lse_all, delta_all = lse_ref[0], delta_ref[0]
    k_pos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    def body(iq, carry):
        dk_acc, dv_acc = carry
        q = jax.lax.dynamic_slice(
            q_all, (iq * block_q, 0), (block_q, d)).astype(jnp.float32)
        do = jax.lax.dynamic_slice(
            do_all, (iq * block_q, 0), (block_q, d)).astype(jnp.float32)
        lse = jax.lax.dynamic_slice(lse_all, (iq * block_q,),
                                    (block_q,))[:, None]
        delta = jax.lax.dynamic_slice(delta_all, (iq * block_q,),
                                      (block_q,))[:, None]
        s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, 1), 0)
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        p = jnp.exp(s - lse)                                 # (bq, bk)
        dv_acc = dv_acc + jnp.dot(p.T, do,
                                  preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_acc = dk_acc + jnp.dot(ds.T, q,
                                  preferred_element_type=jnp.float32)
        return dk_acc, dv_acc

    # Q blocks strictly before this KV block are fully masked; skip them.
    start = (jk * block_k) // block_q
    zeros = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(start, seq // block_q, body, (zeros, zeros))
    dk_ref[0] = (scale * dk).astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _flash_bwd(q, k, v, o, lse, do, block_q: int, block_k: int):
    bh, t, d = q.shape
    scale = 1.0 / (d ** 0.5)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)  # (bh, t)

    full = lambda bh_, i: (bh_, 0, 0)
    full1 = lambda bh_, i: (bh_, 0)
    qtile = lambda bh_, i: (bh_, i, 0)
    qtile1 = lambda bh_, i: (bh_, i)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_k=block_k, scale=scale),
        grid=(bh, t // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), qtile),
            pl.BlockSpec((1, t, d), full),
            pl.BlockSpec((1, t, d), full),
            pl.BlockSpec((1, block_q, d), qtile),
            pl.BlockSpec((1, block_q), qtile1),
            pl.BlockSpec((1, block_q), qtile1),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), qtile),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=block_q, scale=scale),
        grid=(bh, t // block_k),
        in_specs=[
            pl.BlockSpec((1, t, d), full),
            pl.BlockSpec((1, block_k, d), qtile),
            pl.BlockSpec((1, block_k, d), qtile),
            pl.BlockSpec((1, t, d), full),
            pl.BlockSpec((1, t), full1),
            pl.BlockSpec((1, t), full1),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), qtile),
            pl.BlockSpec((1, block_k, d), qtile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), k.dtype),
            jax.ShapeDtypeStruct((bh, t, d), v.dtype),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attention_flat(q, k, v, block_q: int, block_k: int):
    o, _ = _flash_fwd(q, k, v, block_q, block_k)
    return o


def _flash_vjp_fwd(q, k, v, block_q, block_k):
    o, lse = _flash_fwd(q, k, v, block_q, block_k)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(block_q, block_k, res, do):
    q, k, v, o, lse = res
    return _flash_bwd(q, k, v, o, lse, do, block_q, block_k)


_flash_attention_flat.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, block_q: int = BLOCK_Q,
                    block_k: int = BLOCK_K):
    """Causal multi-head attention via the Pallas kernels (differentiable).

    Args:
      q, k, v: ``(batch, heads, seq, head_dim)``.
    Returns:
      ``(batch, heads, seq, head_dim)`` attention output, same dtype as q.
    """
    b, h, t, d = q.shape
    assert k.shape == (b, h, t, d) and v.shape == (b, h, t, d)
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)

    # Collapse (batch, heads) into one grid axis.
    out = _flash_attention_flat(
        q.reshape(b * h, t, d), k.reshape(b * h, t, d),
        v.reshape(b * h, t, d), block_q, block_k)
    return out.reshape(b, h, t, d)
