"""Pure-jnp correctness oracles for the Layer-1 kernels.

These are the ground truth the Pallas kernels (and, transitively, the AOT
artifacts the rust runtime executes) are validated against in pytest.
Everything here is written for clarity, not speed.
"""

from __future__ import annotations

import jax.numpy as jnp


def causal_attention(q, k, v):
    """Naive causal multi-head attention.

    Args:
      q, k, v: ``(batch, heads, seq, head_dim)``.
    Returns:
      ``(batch, heads, seq, head_dim)``.
    """
    b, h, t, d = q.shape
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def log_softmax(logits):
    """Numerically-stable log softmax over the last axis."""
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    return z - jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))


def token_logprobs(logits, tokens):
    """Per-token log p(tokens[t] | tokens[<t]).

    Args:
      logits: ``(batch, seq, vocab)`` — logits[:, t] predicts tokens[:, t+1].
      tokens: ``(batch, seq)`` int32.
    Returns:
      ``(batch, seq)`` f32; position 0 (no prediction context) is 0.
    """
    logp = log_softmax(logits)
    # logits at t-1 score tokens at t
    scored = jnp.take_along_axis(
        logp[:, :-1, :], tokens[:, 1:, None], axis=-1)[..., 0]
    return jnp.pad(scored, ((0, 0), (1, 0)))


def entropy(logits):
    """Per-position softmax entropy, ``(batch, seq)``."""
    logp = log_softmax(logits)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)
