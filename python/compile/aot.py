"""AOT compile path: lower the L2 model (with its L1 Pallas kernel) to
HLO *text* artifacts + a manifest the rust runtime loads.

Run once via ``make artifacts``; python never appears on the request path.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Each exported function is lowered once per **context bucket** (sequence
length). Buckets are the artifact-level analogue of the paper's dynamic
parallelism: the rust coordinator monitors the live context length and
picks the executable for the smallest bucket that fits (Parallelism
Selector, paper §2), instead of always paying for the maximum context.

Outputs (in --out-dir, default ``artifacts/``):
  {fn}_b{batch}_t{bucket}.hlo.txt   one per (function, bucket)
  params.bin                        initial params, f32 LE, param_spec order
  manifest.json                     config + ABI: shapes, order, artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

FUNCTIONS = ("logits", "logprobs", "train_step")


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_structs(cfg: M.ModelConfig):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_spec(cfg)]


def _f32():
    return jax.ShapeDtypeStruct((), jnp.float32)


def lower_function(cfg: M.ModelConfig, fn: str, batch: int, seq: int):
    """Lower one exported function at one context bucket to HLO text."""
    p = _param_structs(cfg)
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    bt_f32 = jax.ShapeDtypeStruct((batch, seq), jnp.float32)

    if fn == "logits":
        args = (*p, tokens)
        f = lambda *a: M.logits_fn(cfg, *a)
    elif fn == "logprobs":
        args = (*p, tokens)
        f = lambda *a: M.logprobs_fn(cfg, *a)
    elif fn == "train_step":
        args = (*p, *p, *p, tokens, bt_f32, bt_f32, bt_f32,
                _f32(), _f32(), _f32(), _f32())
        f = lambda *a: M.train_step_fn(cfg, *a)
    else:
        raise ValueError(f"unknown function {fn!r}")

    lowered = jax.jit(f).lower(*args)
    return to_hlo_text(lowered)


def io_signature(cfg: M.ModelConfig, fn: str, batch: int, seq: int):
    """Human/rust-readable description of the positional ABI."""
    n = len(M.param_spec(cfg))
    if fn in ("logits", "logprobs"):
        ins = [f"params[{n}]", "tokens:i32[b,t]"]
        outs = ["logits:f32[b,t,v]"] if fn == "logits" \
            else ["logprobs:f32[b,t]"]
    else:
        ins = [f"params[{n}]", f"adam_m[{n}]", f"adam_v[{n}]",
               "tokens:i32[b,t]", "mask:f32[b,t]", "advantages:f32[b,t]",
               "ref_logprobs:f32[b,t]", "step:f32", "lr:f32",
               "ent_coef:f32", "kl_coef:f32"]
        outs = [f"params[{n}]", f"adam_m[{n}]", f"adam_v[{n}]",
                "loss:f32", "pg:f32", "kl:f32", "entropy:f32"]
    return {"inputs": ins, "outputs": outs, "batch": batch, "seq": seq}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--buckets", default="128,256,512",
                    help="comma-separated context buckets")
    ap.add_argument("--functions", default=",".join(FUNCTIONS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    buckets = sorted(int(b) for b in args.buckets.split(","))
    assert buckets[-1] <= cfg.max_seq, (buckets, cfg.max_seq)
    fns = [f.strip() for f in args.functions.split(",") if f.strip()]
    os.makedirs(args.out_dir, exist_ok=True)

    # --- initial params blob -------------------------------------------------
    params = M.init_params(cfg, seed=args.seed)
    blob = b"".join(np.asarray(p, dtype="<f4").tobytes() for p in params)
    params_path = os.path.join(args.out_dir, "params.bin")
    with open(params_path, "wb") as f:
        f.write(blob)
    print(f"params.bin: {len(blob)} bytes "
          f"({sum(int(math.prod(s)) for _, s in M.param_spec(cfg))} f32)")

    # --- HLO artifacts --------------------------------------------------------
    artifacts = []
    for fn in fns:
        for seq in buckets:
            t0 = time.time()
            text = lower_function(cfg, fn, args.batch, seq)
            name = f"{fn}_b{args.batch}_t{seq}.hlo.txt"
            with open(os.path.join(args.out_dir, name), "w") as f:
                f.write(text)
            artifacts.append({
                "function": fn,
                "bucket": seq,
                "file": name,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                **io_signature(cfg, fn, args.batch, seq),
            })
            print(f"{name}: {len(text)} chars ({time.time() - t0:.1f}s)")

    # --- manifest --------------------------------------------------------------
    manifest = {
        "version": 1,
        "preset": args.preset,
        "seed": args.seed,
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta,
            "n_params": sum(int(math.prod(s))
                            for _, s in M.param_spec(cfg)),
        },
        "batch": args.batch,
        "buckets": buckets,
        "param_spec": [{"name": n, "shape": list(s)}
                       for n, s in M.param_spec(cfg)],
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS,
                 "zero_init": True},
        "params_file": "params.bin",
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest.json: {len(artifacts)} artifacts, "
          f"preset={args.preset}, buckets={buckets}")


if __name__ == "__main__":
    main()
