# Repo-level targets. The rust crate lives in rust/; the AOT artifacts
# it executes are produced by the python compile path.

.PHONY: check check-core analyze fmt lint test artifacts bench-pipeline bench-replan bench-artifacts

# Full gate: formatting, clippy (warnings are errors), the earl-analyze
# static-analysis pass, tier-1 tests, plus the XLA-free core build
# (dispatch/selector/metrics, no XLA_EXTENSION_DIR needed).
check: fmt lint check-core analyze
	cd rust && cargo build --release && cargo test -q

# Static-analysis gate (hard-fails `make check`): concurrency
# discipline (lock-order inversions, channels under guards, wall-clock
# in deterministic stages), wire-protocol consistency (dispatch/wire.rs
# parsed into a machine-readable spec and cross-checked), and the
# ratcheting panic budget (rust/analyze-baseline.json; regenerate with
# `cargo run --bin earl-analyze -- --write-baseline` only to ratchet
# DOWN). Runs on the no-default-features build so it shares the
# check-core compile cache and needs no XLA toolchain.
analyze:
	cd rust && cargo run --release --no-default-features --bin earl-analyze

# The `--no-default-features` core: proves the dispatcher (real-payload
# wire format, TCP runtime, `earl worker`), selector, and metrics build
# and pass without the xla toolchain. The remote-ingest integration
# test (2 `earl worker --ingest` processes reproducing the serial
# learning curve + failure injection), the worker-death chaos test
# (3 processes, kill schedule mid-run, bit-identical curve through the
# tree merge), the fleet-rollout integration test (an `earl worker
# --rollout` fleet at --max-staleness 0 reproducing the serial curve
# bit-for-bit), and the elastic-fleet chaos test (kill a rollout
# worker, rejoin it two steps later, curve unchanged) run here by
# construction — they are re-run explicitly so a feature-gating
# regression cannot silently filter them out of the suite.
check-core:
	cd rust && cargo build --release --no-default-features
	cd rust && cargo test -q --no-default-features
	cd rust && cargo test -q --no-default-features --test integration_remote_ingest
	cd rust && cargo test -q --no-default-features --test chaos_worker_death
	cd rust && cargo test -q --no-default-features --test integration_fleet_rollout
	cd rust && cargo test -q --no-default-features --test chaos_fleet_rejoin
	cd rust && cargo bench --no-default-features --bench fig6_replan -- --smoke

fmt:
	cd rust && cargo fmt --check

lint:
	cd rust && cargo clippy --all-targets -- -D warnings

test:
	cd rust && cargo test -q

# AOT-lower the JAX model to HLO-text artifacts for the rust runtime.
# Idempotent: skips when the manifest already exists (delete
# rust/artifacts to force a rebuild).
artifacts:
	@if [ -f rust/artifacts/manifest.json ]; then \
		echo "rust/artifacts already present — skipping (rm -r rust/artifacts to regenerate)"; \
	else \
		cd python/compile && python3 aot.py --out-dir ../../rust/artifacts; \
	fi

# Fig. 5 (ours): serial vs overlapped vs overlapped-async steps/sec;
# emits BENCH_pipeline.json.
bench-pipeline:
	cd rust && cargo bench --bench fig5_pipeline

# XLA-free: the full ramp writes rust/BENCH_replan.json.
bench-replan:
	cd rust && cargo bench --bench fig6_replan

# Regenerate every committed deterministic bench artifact
# (rust/BENCH_dispatch.json, rust/BENCH_pipeline.json,
# rust/BENCH_replan.json). All three carry only cost-model numbers at
# stable 6-decimal rounding — wall-clock measurements print to the
# bench tables but never enter the JSON — so the files must come out
# byte-identical on any machine.
bench-artifacts:
	cd rust && cargo bench --bench fig4_dispatch
	cd rust && cargo bench --bench fig5_pipeline
	cd rust && cargo bench --no-default-features --bench fig6_replan
