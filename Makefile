# Repo-level targets. The rust crate lives in rust/; the AOT artifacts
# it executes are produced by the python compile path.

.PHONY: check test artifacts bench-pipeline

# Tier-1 verify + lint gate.
check:
	cd rust && cargo build --release && cargo test -q && cargo clippy -- -D warnings

test:
	cd rust && cargo test -q

# AOT-lower the JAX model to HLO-text artifacts for the rust runtime.
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../rust/artifacts

# Fig. 5 (ours): serial vs overlapped steps/sec; emits BENCH_pipeline.json.
bench-pipeline:
	cd rust && cargo bench --bench fig5_pipeline
